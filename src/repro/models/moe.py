"""Mixture-of-Experts: top-k router + two execution paths.

``dense``  — dropless reference: every expert runs over all tokens with a
             gate mask. O(E * T * d * ff) — only for tests / tiny configs.
``ep_tp``  — production path: experts sharded over the 'model' mesh axis
             (expert parallelism folded into tensor parallelism). Activations
             at the MoE input are replicated over 'model' (standard Megatron
             layer boundary), so each model shard *already owns* every token:
             dispatch is a purely local sort/gather into (E_local, C, d)
             capacity buffers, expert FFNs run as batched local matmuls, and
             the combine psum over 'model' replaces the row-parallel
             all-reduce a dense MLP would need anyway — zero extra
             collectives vs dense TP, and zero one-hot-einsum FLOPs (the
             GShard dispatch einsum would cost ~E*C/(k*ff) times the useful
             expert compute: 400x for 256-expert top-8 — see DESIGN.md).

Optionally (RunConfig.fsdp_experts) expert weights are stored sharded over
'data' along the ff dim (ZeRO-3 style) and all-gathered transiently per
layer inside the shard_map body.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, MoEConfig, RunConfig
from repro.models import layers as L
from repro.launch.mesh import compat_axis_size, compat_shard_map


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def init_moe(key, cfg: ModelConfig):
    m = cfg.moe
    d = cfg.d_model
    ks = jax.random.split(key, 5)
    p = {
        "router": L.dense_init(ks[0], (d, m.n_experts)),
        "w_gate": L.dense_init(ks[1], (m.n_experts, d, m.d_ff_expert)),
        "w_up": L.dense_init(ks[2], (m.n_experts, d, m.d_ff_expert)),
        "w_down": L.dense_init(ks[3], (m.n_experts, m.d_ff_expert, d),
                               in_axis_size=m.d_ff_expert),
    }
    if m.n_shared_experts:
        p["shared"] = L.init_mlp(
            ks[4], d, m.d_ff_expert * m.n_shared_experts, "swiglu")
    return p


# ---------------------------------------------------------------------------
# routing
# ---------------------------------------------------------------------------


def route(router_w, x, m: MoEConfig):
    """x: (T, d) -> gates (T, k) normalized, idx (T, k), aux load-balance loss.

    Softmax router with top-k renormalization (OLMoE); the DeepSeek-V3
    sigmoid+bias variant differs only in the score nonlinearity — the
    balancing aux term below is the standard switch-style load loss.
    """
    logits = jnp.einsum("td,de->te", x, router_w.astype(x.dtype))
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    gates, idx = lax.top_k(probs, m.top_k)
    gates = gates / jnp.maximum(jnp.sum(gates, -1, keepdims=True), 1e-9)
    # aux: E * mean(frac_tokens_e * mean_prob_e)
    E = m.n_experts
    onehot = jax.nn.one_hot(idx[:, 0], E, dtype=jnp.float32)
    frac = jnp.mean(onehot, axis=0)
    mprob = jnp.mean(probs, axis=0)
    aux = E * jnp.sum(frac * mprob)
    return gates.astype(x.dtype), idx, aux


# ---------------------------------------------------------------------------
# dense (dropless) reference path
# ---------------------------------------------------------------------------


def moe_dense(params, x, cfg: ModelConfig):
    """x: (B,S,d). Every expert processes all tokens; gate-masked combine."""
    m = cfg.moe
    B, S, d = x.shape
    xt = x.reshape(B * S, d)
    gates, idx, aux = route(params["router"], xt, m)
    # combine weights (T, E)
    comb = jnp.zeros((B * S, m.n_experts), x.dtype)
    t = jnp.arange(B * S)
    for j in range(m.top_k):
        comb = comb.at[t, idx[:, j]].add(gates[:, j])
    g = jnp.einsum("td,edf->tef", xt, params["w_gate"].astype(x.dtype))
    u = jnp.einsum("td,edf->tef", xt, params["w_up"].astype(x.dtype))
    h = jax.nn.silu(g) * u
    y = jnp.einsum("tef,efd->ted", h, params["w_down"].astype(x.dtype))
    out = jnp.einsum("ted,te->td", y, comb)
    out = out.reshape(B, S, d)
    if m.n_shared_experts:
        out = out + L.mlp(params["shared"], x, "swiglu")
    return out, aux


# ---------------------------------------------------------------------------
# EP path: local sort/gather dispatch, experts over 'model'
# ---------------------------------------------------------------------------


def _local_expert_ffn(w_gate, w_up, w_down, xb):
    """xb: (E_local, C, d) capacity buffers -> (E_local, C, d)."""
    g = jnp.einsum("ecd,edf->ecf", xb, w_gate)
    u = jnp.einsum("ecd,edf->ecf", xb, w_up)
    h = jax.nn.silu(g) * u
    return jnp.einsum("ecf,efd->ecd", h, w_down)


def _dispatch_local(xt, idx, gates, e_lo, E_local: int, C: int):
    """Gather tokens assigned to experts [e_lo, e_lo+E_local) into capacity
    buffers. xt: (T, d); idx/gates: (T, k); e_lo may be traced (axis_index).

    Returns xb (E_l, C, d) token buffers, src (E_l, C) source-token index
    (-1 = empty slot), w (E_l, C) gate weights. Sort-based: O(Tk log Tk)
    dispatch with *no* one-hot einsum FLOPs. Scatters use .add so that the
    masked-out entries (which all target slot (0,0) with value 0) can never
    clobber a real token.
    """
    T, k = idx.shape
    flat_e = idx.reshape(-1)                       # (T*k,)
    flat_g = gates.reshape(-1)
    flat_t = jnp.repeat(jnp.arange(T), k)
    le = flat_e - e_lo                             # local expert id
    is_local = (le >= 0) & (le < E_local)
    le_key = jnp.where(is_local, le, E_local)      # sentinel sorts last
    order = jnp.argsort(le_key, stable=True)
    le_s = le_key[order]
    t_s = flat_t[order]
    g_s = flat_g[order]
    counts = jnp.bincount(le_key, length=E_local + 1)[:E_local]
    starts = jnp.concatenate(
        [jnp.zeros((1,), counts.dtype), jnp.cumsum(counts)[:-1]])
    pos = jnp.arange(T * k)
    rank = pos - starts[jnp.clip(le_s, 0, E_local - 1)]
    valid = (le_s < E_local) & (rank < C)
    be = jnp.where(valid, le_s, 0)
    br = jnp.where(valid, rank, 0)
    xb = jnp.zeros((E_local, C, xt.shape[1]), xt.dtype).at[be, br].add(
        jnp.where(valid[:, None], xt[t_s], 0))
    w = jnp.zeros((E_local, C), gates.dtype).at[be, br].add(
        jnp.where(valid, g_s, 0))
    src = (jnp.zeros((E_local, C), jnp.int32).at[be, br].add(
        jnp.where(valid, t_s + 1, 0)) - 1)
    return xb, src, w


def _moe_ep_body(x, router_w, w_gate, w_up, w_down, shared, *,
                 m: MoEConfig, fsdp: bool, axis_names=("data", "model"),
                 mlp_kind: str = "swiglu"):
    """shard_map body. x: (B_l, S, d) local batch shard, replicated over
    'model'. w_*: (E_local, d, ff[/data]) local expert shards."""
    if fsdp:
        w_gate = lax.all_gather(w_gate, "data", axis=2, tiled=True)
        w_up = lax.all_gather(w_up, "data", axis=2, tiled=True)
        w_down = lax.all_gather(w_down, "data", axis=1, tiled=True)
    B_l, S, d = x.shape
    xt = x.reshape(B_l * S, d)
    gates, idx, aux = route(router_w, xt, m)
    E_local = w_gate.shape[0]
    shard = lax.axis_index("model")
    e_lo = shard * E_local
    T = B_l * S
    C = max(1, int(T * m.top_k * m.capacity_factor / m.n_experts))
    xb, src, w = _dispatch_local(xt, idx, gates, e_lo, E_local, C)
    yb = _local_expert_ffn(w_gate.astype(x.dtype), w_up.astype(x.dtype),
                           w_down.astype(x.dtype), xb)
    # combine: scatter-add back to token buffer, weighted
    out = jnp.zeros((T, d), x.dtype)
    flat_src = src.reshape(-1)
    flat_y = (yb * w[..., None].astype(yb.dtype)).reshape(-1, d)
    ok = flat_src >= 0
    out = out.at[jnp.where(ok, flat_src, 0)].add(
        jnp.where(ok[:, None], flat_y, 0))
    out = lax.psum(out, "model")
    aux = lax.pmean(aux, tuple(axis_names))   # replicated scalar
    out = out.reshape(B_l, S, d)
    if shared:
        out = out + L.mlp(shared, x, mlp_kind)
    return out, aux


def moe_ep(params, x, cfg: ModelConfig, run: RunConfig, mesh):
    """Expert-parallel MoE via shard_map on `mesh` (axes pod?/data/model)."""
    m = cfg.moe
    batch_axes = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    xspec = P(batch_axes, None, None)
    ff_spec = "data" if run.fsdp_experts else None
    body = functools.partial(_moe_ep_body, m=m, fsdp=run.fsdp_experts,
                             axis_names=tuple(mesh.axis_names))
    shared = params.get("shared", {})
    fn = compat_shard_map(
        body, mesh=mesh,
        in_specs=(xspec, P(None, None),
                  P("model", None, ff_spec), P("model", None, ff_spec),
                  P("model", ff_spec, None), P()),
        out_specs=(xspec, P()),
        check_vma=False)
    return fn(x, params["router"], params["w_gate"], params["w_up"],
              params["w_down"], shared)


# ---------------------------------------------------------------------------
# EP over (model x data): DeepSeek-style all-to-all expert parallelism.
# Experts sharded E/(M*D) per device — no ff-dim FSDP, so no per-microbatch
# weight all-gathers (the dominant collective in the fsdp_experts baseline:
# ~1.4 GiB of expert weights re-gathered per layer per microbatch). Tokens
# travel to their expert's data shard via all_to_all over 'data' (wire =
# 2 * T_local * topk * d bytes per layer) and partial outputs combine with
# the same psum('model') the TP MLP needs anyway.
# ---------------------------------------------------------------------------


def _moe_ep_a2a_body(x, router_w, w_gate, w_up, w_down, shared, *,
                     m: MoEConfig, axis_names, data_axis="data",
                     mlp_kind: str = "swiglu"):
    B_l, S, d = x.shape
    xt = x.reshape(B_l * S, d)
    T = B_l * S
    gates, idx, aux = route(router_w, xt, m)
    E_local = w_gate.shape[0]                 # experts on THIS device
    M = compat_axis_size("model")
    D = compat_axis_size(data_axis)
    m_idx = lax.axis_index("model")
    # expert e lives on (m = e // (D*E_local), d = (e // E_local) % D)
    # this m-shard only handles its own experts; others contribute via the
    # final psum over 'model'
    per_m = D * E_local
    e_lo_m = m_idx * per_m
    le = idx - e_lo_m                          # (T, k) local-to-m expert id
    mine = (le >= 0) & (le < per_m)
    owner_d = jnp.where(mine, le // E_local, D)     # D = sentinel
    slot = jnp.where(mine, le % E_local, 0)
    # send capacity per destination data shard: this m-shard only forwards
    # the 1/M fraction of assignments owned by its experts, spread over D
    # destinations
    C_send = max(1, int(T * m.top_k * m.capacity_factor / (D * M)))
    flat_t = jnp.repeat(jnp.arange(T), m.top_k)
    flat_g = gates.reshape(-1)
    flat_dst = owner_d.reshape(-1)
    flat_slot = slot.reshape(-1)
    # rank within destination bucket (sort-based, as in _dispatch_local)
    order = jnp.argsort(jnp.where(flat_dst < D, flat_dst, D), stable=True)
    dst_s = flat_dst[order]
    t_s = flat_t[order]
    g_s = flat_g[order]
    slot_s = flat_slot[order]
    counts = jnp.bincount(jnp.clip(dst_s, 0, D), length=D + 1)[:D]
    starts = jnp.concatenate(
        [jnp.zeros((1,), counts.dtype), jnp.cumsum(counts)[:-1]])
    rank = jnp.arange(T * m.top_k) - starts[jnp.clip(dst_s, 0, D - 1)]
    valid = (dst_s < D) & (rank < C_send)
    bd = jnp.where(valid, dst_s, 0)
    br = jnp.where(valid, rank, 0)
    send_x = jnp.zeros((D, C_send, d), xt.dtype).at[bd, br].add(
        jnp.where(valid[:, None], xt[t_s], 0))
    meta = jnp.stack([(t_s + 1).astype(jnp.float32),
                      slot_s.astype(jnp.float32)], -1)
    send_meta = jnp.zeros((D, C_send, 2), jnp.float32).at[bd, br].add(
        jnp.where(valid[:, None], meta, 0))
    # exchange: every shard sends bucket j to data-shard j
    recv_x = lax.all_to_all(send_x, data_axis, 0, 0, tiled=False)
    recv_meta = lax.all_to_all(send_meta, data_axis, 0, 0, tiled=False)
    # recv_*: (D, C_send, ...) — tokens from every source shard
    rx = recv_x.reshape(D * C_send, d)
    rsrc = recv_meta[..., 0].reshape(-1).astype(jnp.int32) - 1  # -1 = empty
    rslot = recv_meta[..., 1].reshape(-1).astype(jnp.int32)
    ok = rsrc >= 0
    # gather into per-local-expert capacity buffers (slack is already in
    # C_send via capacity_factor)
    C_loc = max(1, (D * C_send) // max(E_local, 1))
    C_loc = min(C_loc, D * C_send)
    key = jnp.where(ok, rslot, E_local)
    order2 = jnp.argsort(key, stable=True)
    k_s = key[order2]
    counts2 = jnp.bincount(k_s, length=E_local + 1)[:E_local]
    starts2 = jnp.concatenate(
        [jnp.zeros((1,), counts2.dtype), jnp.cumsum(counts2)[:-1]])
    rank2 = jnp.arange(D * C_send) - starts2[jnp.clip(k_s, 0, E_local - 1)]
    valid2 = (k_s < E_local) & (rank2 < C_loc)
    be = jnp.where(valid2, k_s, 0)
    br2 = jnp.where(valid2, rank2, 0)
    xb = jnp.zeros((E_local, C_loc, d), xt.dtype).at[be, br2].add(
        jnp.where(valid2[:, None], rx[order2], 0))
    yb = _local_expert_ffn(w_gate.astype(x.dtype), w_up.astype(x.dtype),
                           w_down.astype(x.dtype), xb)
    # scatter expert outputs back to the recv layout, then reverse a2a
    y_flat = jnp.zeros((D * C_send, d), x.dtype).at[
        jnp.where(valid2, order2, 0)].add(
        jnp.where(valid2[:, None], yb[be, br2], 0))
    y_send = y_flat.reshape(D, C_send, d)
    y_back = lax.all_to_all(y_send, data_axis, 0, 0, tiled=False)
    # combine at source: weight by gate, scatter-add per token
    out = jnp.zeros((T, d), x.dtype)
    yb_flat = y_back.reshape(-1, d)
    out = out.at[jnp.where(valid, t_s, 0)].add(
        jnp.where(valid[:, None],
                  (yb_flat[bd * C_send + br] *
                   jnp.where(valid, g_s, 0)[:, None].astype(x.dtype)), 0))
    out = lax.psum(out, "model")
    aux = lax.pmean(aux, tuple(axis_names))
    out = out.reshape(B_l, S, d)
    if shared:
        out = out + L.mlp(shared, x, mlp_kind)
    return out, aux


def moe_ep_a2a(params, x, cfg: ModelConfig, run: RunConfig, mesh):
    m = cfg.moe
    batch_axes = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    xspec = P(batch_axes, None, None)
    body = functools.partial(_moe_ep_a2a_body, m=m,
                             axis_names=tuple(mesh.axis_names))
    shared = params.get("shared", {})
    espec = P(("model", "data"), None, None)
    fn = compat_shard_map(
        body, mesh=mesh,
        in_specs=(xspec, P(None, None), espec, espec,
                  P(("model", "data"), None, None), P()),
        out_specs=(xspec, P()),
        check_vma=False)
    return fn(x, params["router"], params["w_gate"], params["w_up"],
              params["w_down"], shared)


def moe(params, x, cfg: ModelConfig, run: RunConfig, mesh=None):
    if mesh is not None and "model" in mesh.axis_names:
        if cfg.moe.impl == "ep_a2a":
            return moe_ep_a2a(params, x, cfg, run, mesh)
        if cfg.moe.impl == "ep_tp":
            return moe_ep(params, x, cfg, run, mesh)
    return moe_dense(params, x, cfg)
