"""RWKV6 ("Finch") block: token-shift time-mix with data-dependent decay,
WKV linear-attention recurrence, and squared-ReLU channel-mix.

Recurrence per head (state S: (K, V), K = V = head_dim):
    S_t = diag(w_t) S_{t-1} + k_t v_t^T          w_t in (0,1), data-dependent
    y_t = r_t^T (S_{t-1} + diag(u) k_t v_t^T)

Chunked closed form (cum[i] = sum_{k<=i} log w_k, exponents <= 0, stable):
    A[t,j]  = sum_K r_t[K] k_j[K] exp(cum[t-1,K] - cum[j,K])   (j < t)
    y_t     = sum_j A[t,j] v_j + (r_t . (u*k_t)) v_t + r_t^T diag(exp(cum[t-1])) S_in
The per-channel decay makes A a 3-tensor contraction — this is the
perf-critical op the Pallas wkv6 kernel tiles (repro/kernels/wkv6.py).

Fidelity note (DESIGN.md): decay w is data-dependent via the Finch LoRA
(w = exp(-exp(w0 + tanh(x @ A) @ B))); the r/k/v/g token-shift mixes use
static learned coefficients (full Finch also LoRAs those — the decay is the
architecturally significant part and is reproduced exactly).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig, RunConfig
from repro.models import layers as L


def init_rwkv6(key, cfg: ModelConfig):
    d = cfg.d_model
    H = cfg.n_heads
    K = d // H
    ks = jax.random.split(key, 12)
    lora = max(32, d // 64)
    return {
        "mix": 0.5 * jnp.ones((5, d)),          # mu for r,k,v,g,w
        "wr": L.dense_init(ks[0], (d, H, K)),
        "wk": L.dense_init(ks[1], (d, H, K)),
        "wv": L.dense_init(ks[2], (d, H, K)),
        "wg": L.dense_init(ks[3], (d, d)),
        "w0": jnp.zeros((H, K)) - 0.6,          # base decay ~ exp(-exp(-0.6))
        "w_lora_a": L.dense_init(ks[4], (d, lora)),
        "w_lora_b": L.dense_init(ks[5], (lora, H, K), in_axis_size=lora) * 0.1,
        "u": 0.1 * jax.random.normal(ks[6], (H, K)),
        "ln_x": jnp.ones((d,)),                 # per-head group norm scale
        "wo": L.dense_init(ks[7], (d, d)),
        # channel mix
        "cm_mix": 0.5 * jnp.ones((2, d)),
        "cm_k": L.dense_init(ks[8], (d, cfg.d_ff)),
        "cm_v": L.dense_init(ks[9], (cfg.d_ff, d), in_axis_size=cfg.d_ff),
        "cm_r": L.dense_init(ks[10], (d, d)),
    }


def _token_shift(x, last=None):
    """x_{t-1} with zero (or carried `last`) at t=0. x: (B,S,d)."""
    prev = jnp.roll(x, 1, axis=1)
    first = jnp.zeros_like(x[:, :1]) if last is None else last[:, None, :]
    return jnp.concatenate([first, prev[:, 1:]], axis=1)


def _decay(params, xw):
    """Data-dependent log-decay lw (B,S,H,K), <= -exp(w0-ish) < 0."""
    lo = jnp.tanh(jnp.einsum("bsd,dr->bsr", xw,
                             params["w_lora_a"].astype(xw.dtype)))
    ww = params["w0"].astype(jnp.float32) + \
        jnp.einsum("bsr,rhk->bshk", lo, params["w_lora_b"].astype(xw.dtype)
                   ).astype(jnp.float32)
    return -jnp.exp(ww)          # log w_t = -exp(ww)  =>  w in (0,1)


def time_mix(params, x, cfg: ModelConfig, run: RunConfig, state=None,
             shift_last=None):
    """WKV6 time-mix over a sequence. Returns (out, (new_state, new_last))."""
    B, S, d = x.shape
    H = cfg.n_heads
    K = d // H
    xp = _token_shift(x, shift_last)
    mix = params["mix"].astype(x.dtype)
    xr = x + (xp - x) * mix[0]
    xk = x + (xp - x) * mix[1]
    xv = x + (xp - x) * mix[2]
    xg = x + (xp - x) * mix[3]
    xw = x + (xp - x) * mix[4]
    r = jnp.einsum("bsd,dhk->bshk", xr, params["wr"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", xk, params["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", xv, params["wv"].astype(x.dtype))
    g = jax.nn.silu(jnp.einsum("bsd,de->bse", xg, params["wg"].astype(x.dtype)))
    lw = _decay(params, xw)                                   # (B,S,H,K) f32
    u = params["u"].astype(jnp.float32)
    if run.attn_impl == "pallas":
        from repro.kernels import ops as kops
        y, new_state = kops.wkv6(r, k, v, lw, u, state=state)
    else:
        y, new_state = wkv_chunked(r, k, v, lw, u, chunk=16, state=state)
    y = y.reshape(B, S, d).astype(x.dtype)
    # per-head group norm
    yh = y.reshape(B, S, H, K)
    mu = jnp.mean(yh.astype(jnp.float32), -1, keepdims=True)
    var = jnp.var(yh.astype(jnp.float32), -1, keepdims=True)
    yh = ((yh - mu) * jax.lax.rsqrt(var + 64e-5)).astype(x.dtype)
    y = yh.reshape(B, S, d) * params["ln_x"].astype(x.dtype)
    out = jnp.einsum("bsd,de->bse", y * g, params["wo"].astype(x.dtype))
    return out, (new_state, x[:, -1, :])


def wkv_chunked(r, k, v, lw, u, chunk: int, state=None):
    """Chunked WKV6. r,k,v: (B,S,H,K); lw: (B,S,H,K) log-decay (<0);
    u: (H,K). Returns y (B,S,H,K) f32, final state (B,H,K,K) f32
    (state[k_dim, v_dim])."""
    B, S, H, K = r.shape
    Q = min(chunk, S)
    pad = (-S) % Q
    if pad:
        z4 = ((0, 0), (0, pad), (0, 0), (0, 0))
        r, k, v = (jnp.pad(a, z4) for a in (r, k, v))
        lw = jnp.pad(lw, z4)  # pad decay 0 => w=1 (no-op steps)
    nC = (S + pad) // Q
    rc = r.reshape(B, nC, Q, H, K).astype(jnp.float32).transpose(1, 0, 2, 3, 4)
    kc = k.reshape(B, nC, Q, H, K).astype(jnp.float32).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, nC, Q, H, K).astype(jnp.float32).transpose(1, 0, 2, 3, 4)
    wc = lw.reshape(B, nC, Q, H, K).astype(jnp.float32).transpose(1, 0, 2, 3, 4)
    if state is None:
        state = jnp.zeros((B, H, K, K), jnp.float32)

    tri = jnp.arange(Q)[:, None] > jnp.arange(Q)[None, :]       # strict lower

    def per_chunk(S_in, inp):
        rq, kq, vq, wq = inp                                    # (B,Q,H,K)
        cum = jnp.cumsum(wq, axis=1)                            # (B,Q,H,K)
        cum_prev = cum - wq                                     # cum[t-1] = cum[t]-w[t]
        # A[t,j] = sum_K r_t k_j exp(cum_prev[t] - cum[j]), j < t
        expo = cum_prev[:, :, None] - cum[:, None, :]           # (B,t,j,H,K)
        A = jnp.einsum("bthk,bjhk,btjhk->bhtj", rq, kq,
                       jnp.exp(jnp.minimum(expo, 0.0)))
        A = A * tri[None, None]
        diag = jnp.einsum("bthk,hk,bthk->bth", rq, u, kq)       # bonus term
        y = jnp.einsum("bhtj,bjhk->bthk", A, vq)
        y = y + diag[..., None] * vq
        y = y + jnp.einsum("bthk,bhkv->bthv", rq * jnp.exp(cum_prev), S_in)
        # state: S_out = diag(exp(cum[-1])) S_in + sum_j diag(exp(cum[-1]-cum[j])) k_j v_j^T
        tail = jnp.exp(cum[:, -1:] - cum)                       # (B,Q,H,K)
        S_out = S_in * jnp.exp(cum[:, -1])[..., None] + \
            jnp.einsum("bjhk,bjhv->bhkv", kq * tail, vq)
        return S_out, y

    S_fin, ys = lax.scan(per_chunk, state, (rc, kc, vc, wc))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(B, nC * Q, H, K)
    return y[:, :S], S_fin


def wkv_recurrent(r, k, v, lw, u, state=None):
    """Step oracle (tests / decode). Same contract as wkv_chunked."""
    B, S, H, K = r.shape
    if state is None:
        state = jnp.zeros((B, H, K, K), jnp.float32)

    def step(S_t, inp):
        r_t, k_t, v_t, w_t = (a.astype(jnp.float32) for a in inp)
        kv = jnp.einsum("bhk,bhv->bhkv", k_t, v_t)
        y = jnp.einsum("bhk,bhkv->bhv", r_t, S_t + u[None, :, :, None] * kv)
        S_new = S_t * jnp.exp(w_t)[..., None] + kv
        return S_new, y

    S_fin, ys = lax.scan(step, state,
                         tuple(a.transpose(1, 0, 2, 3) for a in (r, k, v, lw)))
    return ys.transpose(1, 0, 2, 3), S_fin


def channel_mix(params, x, state_last=None):
    xp = _token_shift(x, state_last)
    mix = params["cm_mix"].astype(x.dtype)
    xk = x + (xp - x) * mix[0]
    xr = x + (xp - x) * mix[1]
    kk = jnp.einsum("bsd,df->bsf", xk, params["cm_k"].astype(x.dtype))
    kk = jnp.square(jax.nn.relu(kk))
    vv = jnp.einsum("bsf,fd->bsd", kk, params["cm_v"].astype(x.dtype))
    rr = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", xr,
                                   params["cm_r"].astype(x.dtype)))
    return vv * rr, x[:, -1, :]


def rwkv_block(params, x, cfg: ModelConfig, run: RunConfig, norms):
    """Full RWKV6 layer: ln1 -> time-mix -> residual; ln2 -> channel-mix."""
    h, _ = time_mix(params, L.rms_norm(x, norms["ln1"], cfg.norm_eps), cfg, run)
    x = x + h
    h, _ = channel_mix(params, L.rms_norm(x, norms["ln2"], cfg.norm_eps))
    return x + h


def rwkv_block_decode(params, x, cache, cfg: ModelConfig, run: RunConfig,
                      norms):
    """One-token decode. cache: {"wkv": (B,H,K,K), "tm_last": (B,d),
    "cm_last": (B,d)}."""
    xn = L.rms_norm(x, norms["ln1"], cfg.norm_eps)
    h, (wkv, tm_last) = time_mix(params, xn, cfg, run,
                                 state=cache["wkv"],
                                 shift_last=cache["tm_last"])
    x = x + h
    xn = L.rms_norm(x, norms["ln2"], cfg.norm_eps)
    h, cm_last = channel_mix(params, xn, state_last=cache["cm_last"])
    return x + h, {"wkv": wkv, "tm_last": tm_last, "cm_last": cm_last}


def init_rwkv_cache(cfg: ModelConfig, batch: int, dtype):
    d = cfg.d_model
    H = cfg.n_heads
    K = d // H
    return {"wkv": jnp.zeros((batch, H, K, K), jnp.float32),
            "tm_last": jnp.zeros((batch, d), dtype),
            "cm_last": jnp.zeros((batch, d), dtype)}
