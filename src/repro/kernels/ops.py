"""Jitted public wrappers for the Pallas kernels.

On CPU (this container) kernels execute in interpret mode — the kernel body
runs in Python for correctness validation; on TPU the same call compiles to
Mosaic. `interpret=None` auto-detects.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as _fa
from repro.kernels import rmsnorm as _rn
from repro.kernels import ssd as _ssd
from repro.kernels import wkv6 as _wkv


def _auto_interpret(interpret):
    if interpret is None:
        return jax.default_backend() == "cpu"
    return interpret


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_kv",
                                             "interpret"))
def flash_attention(q, k, v, *, causal=True, block_q=512, block_kv=1024,
                    interpret=None):
    return _fa.flash_attention(q, k, v, causal=causal, block_q=block_q,
                               block_kv=block_kv,
                               interpret=_auto_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("eps", "block_rows",
                                             "interpret"))
def rmsnorm(x, scale, *, eps=1e-5, residual=None, block_rows=256,
            interpret=None):
    return _rn.rmsnorm(x, scale, eps=eps, residual=residual,
                       block_rows=block_rows,
                       interpret=_auto_interpret(interpret))


def wkv6(r, k, v, lw, u, *, state=None, chunk=16, interpret=None):
    """Matches models.rwkv.wkv_chunked's (y, state) contract; the kernel
    computes y, the final state (needed only when handing off to serving)
    is reconstructed by one closed-form pass."""
    y = _wkv.wkv6(r, k, v, lw, u, chunk=chunk,
                  interpret=_auto_interpret(interpret))
    if state is not None:
        # kernel assumes zero initial state; correct y by the decayed
        # contribution of the incoming state, then update the state.
        lw32 = lw.astype(jnp.float32)
        cum = jnp.cumsum(lw32, axis=1)
        cum_prev = cum - lw32
        y = y + jnp.einsum("bshk,bhkv->bshv",
                           r.astype(jnp.float32) * jnp.exp(cum_prev), state)
    else:
        state = jnp.zeros((r.shape[0], r.shape[2], r.shape[3], v.shape[3]),
                          jnp.float32)
        lw32 = lw.astype(jnp.float32)
        cum = jnp.cumsum(lw32, axis=1)
    tail = jnp.exp(cum[:, -1:] - cum)
    new_state = state * jnp.exp(cum[:, -1])[..., None] + jnp.einsum(
        "bshk,bshv->bhkv", k.astype(jnp.float32) * tail,
        v.astype(jnp.float32))
    return y, new_state


def ssd(xs, dt, A, Bm, Cm, *, chunk=128, interpret=None):
    return _ssd.ssd(xs, dt, A, Bm, Cm, chunk=chunk,
                    interpret=_auto_interpret(interpret))
