"""Pure-jnp oracles for every Pallas kernel (the allclose targets).

Each mirrors the corresponding kernel contract exactly; the model code's
recurrent/step implementations double as independent second oracles.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool = True):
    """q: (B,Sq,H,D); k,v: (B,Sk,Hkv,D)."""
    B, Sq, H, D = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    qg = q.reshape(B, Sq, Hkv, G, D).astype(jnp.float32)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg, k.astype(jnp.float32))
    s = s / math.sqrt(D)
    if causal:
        Sk = k.shape[1]
        mask = jnp.arange(Sq)[:, None] >= jnp.arange(Sk)[None, :]
        s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskd->bqkgd", p, v.astype(jnp.float32))
    return o.reshape(B, Sq, H, D).astype(q.dtype)


def rmsnorm_ref(x, scale, *, eps: float = 1e-5, residual=None):
    x32 = x.astype(jnp.float32)
    if residual is not None:
        x32 = x32 + residual.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps) *
            scale.astype(jnp.float32)).astype(x.dtype)


def wkv6_ref(r, k, v, lw, u):
    """Step-by-step recurrence. Returns y (B,S,H,K) f32."""
    B, S, H, K = r.shape
    state = jnp.zeros((B, H, K, K), jnp.float32)

    def step(S_t, inp):
        r_t, k_t, v_t, w_t = (a.astype(jnp.float32) for a in inp)
        kv = jnp.einsum("bhk,bhv->bhkv", k_t, v_t)
        y = jnp.einsum("bhk,bhkv->bhv", r_t,
                       S_t + u.astype(jnp.float32)[None, :, :, None] * kv)
        return S_t * jnp.exp(w_t)[..., None] + kv, y

    _, ys = jax.lax.scan(step, state,
                         tuple(a.transpose(1, 0, 2, 3)
                               for a in (r, k, v, lw)))
    return ys.transpose(1, 0, 2, 3)


def ssd_ref(xs, dt, A, Bm, Cm):
    """Step-by-step SSD recurrence. Returns y (B,S,H,P) f32."""
    B, S, H, P = xs.shape
    N = Bm.shape[-1]
    h0 = jnp.zeros((B, H, N, P), jnp.float32)

    def step(h, inp):
        x_t, dt_t, b_t, c_t = inp
        a = jnp.exp(dt_t * A[None, :])
        h = h * a[:, :, None, None] + jnp.einsum(
            "bhn,bhp->bhnp", b_t * dt_t[..., None], x_t)
        return h, jnp.einsum("bhn,bhnp->bhp", c_t, h)

    _, ys = jax.lax.scan(
        step, h0,
        (xs.astype(jnp.float32).transpose(1, 0, 2, 3),
         dt.transpose(1, 0, 2),
         Bm.astype(jnp.float32).transpose(1, 0, 2, 3),
         Cm.astype(jnp.float32).transpose(1, 0, 2, 3)))
    return ys.transpose(1, 0, 2, 3)
