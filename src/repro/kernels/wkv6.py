"""Pallas TPU RWKV6 WKV kernel: chunked linear-attention recurrence with
per-channel data-dependent decay.

Grid = (B, H, n_chunks); the chunk index is innermost/sequential, the
(K, V) state matrix lives in VMEM scratch across chunks. Per chunk
(Q = chunk length, K = head dim):

    cum       = cumsum(log w)                 (Q, K)   VPU
    A[t,j]    = sum_K r_t k_j e^{cum[t-1]-cum[j]}  (strict lower tri)
    y         = A @ V + (r.(u*k)) v  + (r e^{cum[t-1]}) @ S
    S         = diag(e^{cum[-1]}) S + (k e^{cum[-1]-cum})^T V

The (Q, Q, K) decay tensor is materialized tile-by-tile in VMEM
(Q=16 -> 16*16*64*4B = 64 KiB) — this is the op that makes XLA's
unfused lowering HBM-bound and is exactly the paper-style perf hotspot the
kernel removes. All exponents are <= 0: unconditionally stable.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(r_ref, k_ref, v_ref, w_ref, u_ref, y_ref, s_scr, *,
            n_chunks: int, chunk: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        s_scr[...] = jnp.zeros_like(s_scr)

    r = r_ref[0, 0].astype(jnp.float32)        # (Q, K)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    lw = w_ref[0, 0].astype(jnp.float32)       # log-decay <= 0
    u = u_ref[0].astype(jnp.float32)           # (K,)
    S = s_scr[...]                              # (K, V)

    cum = jnp.cumsum(lw, axis=0)               # (Q, K)
    cum_prev = cum - lw
    Q = r.shape[0]
    # A[t, j] = sum_K r_t k_j exp(cum_prev[t] - cum[j]),  j < t
    expo = cum_prev[:, None, :] - cum[None, :, :]          # (t, j, K)
    expo = jnp.minimum(expo, 0.0)
    a3 = (r[:, None, :] * k[None, :, :]) * jnp.exp(expo)   # (t, j, K)
    A = jnp.sum(a3, axis=-1)
    tri = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0) > \
        jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    A = jnp.where(tri, A, 0.0)
    y = jax.lax.dot_general(A, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    diag = jnp.sum(r * u[None, :] * k, axis=-1)            # (Q,)
    y = y + diag[:, None] * v
    y = y + jax.lax.dot_general(r * jnp.exp(cum_prev), S,
                                (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
    # state update
    tail = jnp.exp(cum[-1:, :] - cum)                      # (Q, K)
    s_scr[...] = S * jnp.exp(cum[-1])[:, None] + jax.lax.dot_general(
        (k * tail), v, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    y_ref[0, 0] = y.astype(y_ref.dtype)


def wkv6(r, k, v, lw, u, *, chunk: int = 16, interpret: bool = False):
    """r,k,v,lw: (B, S, H, K); u: (H, K). Returns y (B, S, H, K) f32.
    (Final state is recomputed by the caller when needed — the serving path
    uses the recurrent step.)"""
    B, S, H, K = r.shape
    chunk = min(chunk, S)
    pad = (-S) % chunk
    zero4 = ((0, 0), (0, pad), (0, 0), (0, 0))

    def prep(a):
        a = jnp.moveaxis(a, 2, 1)             # (B, H, S, K)
        if pad:
            a = jnp.pad(a, ((0, 0), (0, 0), (0, pad), (0, 0)))
        return a

    rt, kt, vt = prep(r), prep(k), prep(v)
    wt = prep(lw)
    if pad:
        # padded steps must be identity: log w = 0
        mask = jnp.arange(S + pad) >= S
        wt = jnp.where(mask[None, None, :, None], 0.0, wt)
    n_chunks = (S + pad) // chunk

    kernel = functools.partial(_kernel, n_chunks=n_chunks, chunk=chunk)
    y = pl.pallas_call(
        kernel,
        grid=(B, H, n_chunks),
        in_specs=[
            pl.BlockSpec((1, 1, chunk, K), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, chunk, K), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, chunk, K), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, chunk, K), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, K), lambda b, h, c: (h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, chunk, K), lambda b, h, c: (b, h, c, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, n_chunks * chunk, K),
                                       jnp.float32),
        scratch_shapes=[pltpu.VMEM((K, K), jnp.float32)],
        interpret=interpret,
    )(rt, kt, vt, wt, u)
    return jnp.moveaxis(y, 1, 2)[:, :S]
