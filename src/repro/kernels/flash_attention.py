"""Pallas TPU flash attention (causal / non-causal, GQA).

Tiling: grid = (B, H, num_q_blocks, num_kv_blocks); the kv index is the
innermost (sequential on TPU) grid dimension, so the online-softmax state
(m, l, acc) lives in VMEM scratch and persists across kv steps. Each step
loads a (block_q, D) query tile and a (block_kv, D) KV tile into VMEM,
runs the (block_q x D) @ (D x block_kv) score matmul on the MXU, and
rescales the accumulator. Fully-masked causal tiles are skipped with
pl.when (the compiler elides the DMA for untouched tiles on TPU grids).

GQA: the kv BlockSpec index_map folds the query head h to kv head
h // (H // Hkv) — no KV replication in HBM.

Block defaults (512, 1024) x D=128 keep the working set
(q 512x128 + kv 2x1024x128 + scores 512x1024) * 4B ~= 3.3 MiB well inside
the 16 MiB/core VMEM budget with double buffering.

Validated against ref.attention_ref in interpret mode (CPU) over shape /
dtype / causal sweeps — tests/test_kernels.py.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            causal: bool, scale: float, block_q: int, block_kv: int,
            n_kv: int, seq_kv: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * block_q
    k_start = ki * block_kv

    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)           # (bq, D)
        k = k_ref[0, 0].astype(jnp.float32)           # (bkv, D)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # (bq, bkv)
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = kpos < seq_kv
        if causal:
            mask = mask & (qpos >= kpos)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        msafe = jnp.where(m_new > NEG_INF / 2, m_new, 0.0)
        p = jnp.where(s > NEG_INF / 2, jnp.exp(s - msafe[:, None]), 0.0)
        corr = jnp.where(m_prev > NEG_INF / 2, jnp.exp(m_prev - msafe), 0.0)
        l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=-1)
        acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    if causal:
        # skip tiles entirely above the causal diagonal
        pl.when(q_start + block_q - 1 >= k_start)(_compute)
    else:
        _compute()

    @pl.when(ki == n_kv - 1)
    def _finish():
        l = l_scr[...]
        o_ref[0, 0] = (acc_scr[...] /
                       jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True, block_q: int = 512,
                    block_kv: int = 1024, interpret: bool = False):
    """q: (B, Sq, H, D); k, v: (B, Skv, Hkv, D) -> (B, Sq, H, D)."""
    B, Sq, H, D = q.shape
    _, Sk, Hkv, _ = k.shape
    G = H // Hkv
    scale = 1.0 / math.sqrt(D)
    block_q = min(block_q, Sq)
    block_kv = min(block_kv, Sk)
    pq = (-Sq) % block_q
    pk = (-Sk) % block_kv
    qt = jnp.moveaxis(q, 2, 1)                  # (B, H, Sq, D)
    kt = jnp.moveaxis(k, 2, 1)
    vt = jnp.moveaxis(v, 2, 1)
    if pq:
        qt = jnp.pad(qt, ((0, 0), (0, 0), (0, pq), (0, 0)))
    if pk:
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, pk), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, pk), (0, 0)))
    nq = (Sq + pq) // block_q
    nk = (Sk + pk) // block_kv

    kernel = functools.partial(
        _kernel, causal=causal, scale=scale, block_q=block_q,
        block_kv=block_kv, n_kv=nk, seq_kv=Sk)

    out = pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D),
                         lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, block_kv, D),
                         lambda b, h, i, j, G=G: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, block_kv, D),
                         lambda b, h, i, j, G=G: (b, h // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, D),
                               lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, nq * block_q, D), q.dtype),
        scratch_shapes=[
            # softmax running max / denom + output accumulator, in VMEM
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        interpret=interpret,
    )(qt, kt, vt)
    out = jnp.moveaxis(out, 1, 2)[:, :Sq]
    return out
