"""Pallas TPU fused RMSNorm (+ optional residual add).

One pass over rows: grid over row blocks; each step loads a
(block_rows, d) tile, computes the f32 row RMS on the VPU and writes the
scaled tile — one HBM read + one write instead of the 3+ passes an unfused
mean/rsqrt/mul chain costs when XLA doesn't fuse across the reduction.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, scale_ref, o_ref, *, eps: float, with_residual: bool,
            res_ref=None):
    x = x_ref[...].astype(jnp.float32)
    if with_residual:
        x = x + res_ref[...].astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    o_ref[...] = (y * scale_ref[...].astype(jnp.float32)[None, :]
                  ).astype(o_ref.dtype)


def rmsnorm(x, scale, *, eps: float = 1e-5, residual=None,
            block_rows: int = 256, interpret: bool = False):
    """x: (..., d). Returns rms_norm(x [+ residual]) * scale."""
    orig_shape = x.shape
    d = x.shape[-1]
    xf = x.reshape(-1, d)
    n = xf.shape[0]
    block_rows = min(block_rows, n)
    pad = (-n) % block_rows
    if pad:
        xf = jnp.pad(xf, ((0, pad), (0, 0)))
    rf = None
    if residual is not None:
        rf = residual.reshape(-1, d)
        if pad:
            rf = jnp.pad(rf, ((0, pad), (0, 0)))
    grid = ((n + pad) // block_rows,)
    kernel = functools.partial(_kernel, eps=eps,
                               with_residual=residual is not None)
    in_specs = [pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
                pl.BlockSpec((d,), lambda i: (0,))]
    args = [xf, scale]
    if residual is not None:
        def k2(x_ref, scale_ref, res_ref, o_ref):
            _kernel(x_ref, scale_ref, o_ref, eps=eps, with_residual=True,
                    res_ref=res_ref)
        kernel = k2
        in_specs.append(pl.BlockSpec((block_rows, d), lambda i: (i, 0)))
        args.append(rf)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(xf.shape, x.dtype),
        interpret=interpret,
    )(*args)
    return out[:n].reshape(orig_shape)
