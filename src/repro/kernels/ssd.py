"""Pallas TPU Mamba2 SSD kernel: chunked state-space scan.

Grid = (B, H, n_chunks) with the chunk index sequential; (N, P) state in
VMEM scratch. Per chunk: the intra-chunk (Q, Q) decay-weighted C.B matmul
runs on the MXU; decays are scalar per head so the tile is 2-D (unlike
wkv6's per-channel 3-D decay). All exponents <= 0.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, h_scr, *,
            n_chunks: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    x = x_ref[0, 0].astype(jnp.float32)       # (Q, P)
    dt = dt_ref[0, 0].astype(jnp.float32)     # (Q,)
    A = a_ref[0].astype(jnp.float32)          # scalar (per head), < 0
    Bm = b_ref[0, 0].astype(jnp.float32)      # (Q, N)
    Cm = c_ref[0, 0].astype(jnp.float32)      # (Q, N)
    h = h_scr[...]                             # (N, P)

    la = dt * A                                # (Q,) log decay
    cum = jnp.cumsum(la)
    Q = x.shape[0]
    cb = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (Q, Q)
    dec = jnp.exp(jnp.minimum(cum[:, None] - cum[None, :], 0.0))
    tri = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    M = jnp.where(tri, cb * dec, 0.0) * dt[None, :]
    y = jax.lax.dot_general(M, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    y = y + jnp.exp(cum)[:, None] * jax.lax.dot_general(
        Cm, h, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    tail = jnp.exp(cum[-1] - cum)              # (Q,)
    h_scr[...] = h * jnp.exp(cum[-1]) + jax.lax.dot_general(
        Bm * (tail * dt)[:, None], x, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    y_ref[0, 0] = y.astype(y_ref.dtype)


def ssd(xs, dt, A, Bm, Cm, *, chunk: int = 128, interpret: bool = False):
    """xs: (B,S,H,P); dt: (B,S,H) f32; A: (H,); Bm/Cm: (B,S,H,N).
    Returns (y (B,S,H,P) f32, None)."""
    B, S, H, P = xs.shape
    N = Bm.shape[-1]
    chunk = min(chunk, S)
    pad = (-S) % chunk

    def prep(a):
        a = jnp.moveaxis(a, 2, 1)
        if pad:
            cfg = [(0, 0)] * a.ndim
            cfg[2] = (0, pad)
            a = jnp.pad(a, cfg)
        return a

    xt = prep(xs)
    bt = prep(Bm)
    ct = prep(Cm)
    dtt = jnp.moveaxis(dt, 2, 1)
    if pad:
        dtt = jnp.pad(dtt, ((0, 0), (0, 0), (0, pad)))
    n_chunks = (S + pad) // chunk

    kernel = functools.partial(_kernel, n_chunks=n_chunks)
    y = pl.pallas_call(
        kernel,
        grid=(B, H, n_chunks),
        in_specs=[
            pl.BlockSpec((1, 1, chunk, P), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, chunk), lambda b, h, c: (b, h, c)),
            pl.BlockSpec((1,), lambda b, h, c: (h,)),
            pl.BlockSpec((1, 1, chunk, N), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, chunk, N), lambda b, h, c: (b, h, c, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, chunk, P), lambda b, h, c: (b, h, c, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, n_chunks * chunk, P),
                                       jnp.float32),
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        interpret=interpret,
    )(xt, dtt, A, bt, ct)
    return jnp.moveaxis(y, 1, 2)[:, :S], None
