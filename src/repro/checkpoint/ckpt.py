"""Sharding-aware checkpointing with async writes and elastic restore.

Layout:  <dir>/step_<N>/
            manifest.json       — leaf paths, shapes, dtypes, loader cursor
            <leaf-hash>.npy     — one file per pytree leaf (np.save)

Design points for the 1000-node story (DESIGN.md §5):
* leaves are written as *full logical arrays* (gathered per leaf), so a
  restore can place them onto ANY mesh — elastic scaling = restore with new
  sharding specs; at real pod scale the same manifest format extends to
  per-shard files keyed by (leaf, shard_index) — the restore path already
  reshards via device_put;
* `AsyncCheckpointer` snapshots to host RAM synchronously (cheap) and
  writes in a background thread — the train loop blocks only on the
  previous write (one outstanding checkpoint, bounded memory);
* atomicity: writes go to step_<N>.tmp and are renamed after fsync — a
  preempted save never corrupts the latest-complete checkpoint;
* the data-pipeline cursor travels in the manifest so a resumed run
  continues the token stream exactly.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
from typing import Any, Dict, Optional

import jax
import numpy as np


def _leaf_paths(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        key = jax.tree_util.keystr(path)
        out.append((key, leaf))
    return out


def _fname(key: str) -> str:
    h = hashlib.sha1(key.encode()).hexdigest()[:16]
    return f"leaf_{h}.npy"


def save_checkpoint(directory: str, step: int, tree, extra: Optional[Dict]
                    = None):
    """Blocking save. `tree` may contain jax or numpy arrays."""
    tmp = os.path.join(directory, f"step_{step}.tmp")
    final = os.path.join(directory, f"step_{step}")
    os.makedirs(tmp, exist_ok=True)
    manifest = {"step": step, "leaves": {}, "extra": extra or {}}
    for key, leaf in _leaf_paths(tree):
        if leaf is None:
            continue
        arr = np.asarray(jax.device_get(leaf))
        fn = _fname(key)
        np.save(os.path.join(tmp, fn), arr)
        manifest["leaves"][key] = {
            "file": fn, "shape": list(arr.shape), "dtype": str(arr.dtype)}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = []
    for name in os.listdir(directory):
        if name.startswith("step_") and not name.endswith(".tmp"):
            try:
                steps.append(int(name.split("_")[1]))
            except ValueError:
                continue
    return max(steps) if steps else None


def restore_checkpoint(directory: str, step: int, like, shardings=None):
    """Restore into the structure of `like` (a pytree of arrays or
    ShapeDtypeStructs). `shardings`: optional matching pytree of
    NamedShardings for elastic placement onto a (possibly different) mesh."""
    path = os.path.join(directory, f"step_{step}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    leaves = manifest["leaves"]
    flat = jax.tree_util.tree_flatten_with_path(like)
    shard_flat = None
    if shardings is not None:
        shard_flat = jax.tree_util.tree_flatten(shardings)[0]
    out = []
    for i, (pathk, leaf) in enumerate(flat[0]):
        key = jax.tree_util.keystr(pathk)
        if leaf is None:
            out.append(None)
            continue
        if key not in leaves:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = np.load(os.path.join(path, leaves[key]["file"]))
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(
                f"{key}: checkpoint shape {arr.shape} != {leaf.shape}")
        if shard_flat is not None and shard_flat[i] is not None:
            out.append(jax.device_put(arr, shard_flat[i]))
        else:
            out.append(jax.device_put(arr.astype(leaf.dtype)))
    tree = jax.tree_util.tree_unflatten(flat[1], out)
    return tree, manifest.get("extra", {})


class AsyncCheckpointer:
    """One-outstanding-write async checkpointing."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self.last_error: Optional[Exception] = None

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self.last_error is not None:
            err, self.last_error = self.last_error, None
            raise err

    def save(self, step: int, tree, extra=None):
        self.wait()
        # snapshot to host memory synchronously (device_get), write async
        host_tree = jax.tree.map(
            lambda a: np.asarray(jax.device_get(a)) if a is not None else None,
            tree)

        def work():
            try:
                save_checkpoint(self.directory, step, host_tree, extra)
                self._gc()
            except Exception as e:          # surfaced on next wait()
                self.last_error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def _gc(self):
        steps = sorted(
            int(n.split("_")[1]) for n in os.listdir(self.directory)
            if n.startswith("step_") and not n.endswith(".tmp"))
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s}"),
                          ignore_errors=True)
