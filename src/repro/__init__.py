"""repro: Crispy memory-driven resource allocation for large-scale data
processing, reproduced and extended as a JAX training/serving framework."""

__version__ = "1.0.0"
