"""Train/eval step builders.

`make_train_step(model, acfg, mesh)` returns a pure function
  (state, batch) -> (state, metrics)
with:
  * microbatch gradient accumulation via lax.scan (RunConfig.microbatches) —
    activation memory is bounded by one microbatch; the gradient all-reduce
    XLA inserts at the data/pod boundary happens ONCE per step, after the
    scan (compute/comm overlap: the scan's partial sums stay device-local);
  * optional bf16 gradient compression with f32 error feedback carried in
    the train state (cuts cross-pod DCN bytes in half);
  * AdamW with ZeRO-1-sharded state (sharding specs from sharding/rules.py);
  * cosine-warmup LR schedule.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import RunConfig
from repro.models.model import Model
from repro.optim import (AdamWConfig, OptState, adamw_update, init_adamw,
                         cosine_warmup)
from repro.optim.compression import compress_grads_bf16, init_residual


class TrainState(NamedTuple):
    params: dict
    opt: OptState
    residual: Optional[dict]      # grad-compression error feedback


def init_train_state(model: Model, key, acfg: AdamWConfig) -> TrainState:
    params = model.init(key)
    opt = init_adamw(params, acfg)
    res = init_residual(params) if model.run.grad_compression else None
    return TrainState(params, opt, res)


def make_train_step(model: Model, acfg: AdamWConfig, mesh=None, *,
                    warmup: int = 100, total_steps: int = 10000):
    run = model.run
    n_micro = max(1, run.microbatches)

    def loss_fn(params, batch):
        loss, metrics = model.loss_fn(params, batch, mesh)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(state: TrainState, batch):
        params = state.params

        if n_micro > 1:
            def split(x):
                b = x.shape[0]
                return x.reshape(n_micro, b // n_micro, *x.shape[1:])

            micro = jax.tree.map(split, batch)

            def mb_body(carry, mb):
                g_acc, l_acc = carry
                (loss, _), grads = grad_fn(params, mb)
                g_acc = jax.tree.map(
                    lambda a, g: a + g.astype(a.dtype), g_acc, grads)
                return (g_acc, l_acc + loss), None

            acc_dt = jnp.dtype(run.accum_dtype)
            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, acc_dt), params)
            (grads, loss_sum), _ = lax.scan(mb_body, (g0, 0.0), micro)
            grads = jax.tree.map(lambda g: g / n_micro, grads)
            loss = loss_sum / n_micro
            metrics = {}
        else:
            (loss, metrics), grads = grad_fn(params, batch)

        residual = state.residual
        if run.grad_compression:
            grads, residual = compress_grads_bf16(grads, residual)

        lr_scale = cosine_warmup(state.opt.step, warmup=warmup,
                                 total=total_steps)
        new_params, new_opt, opt_metrics = adamw_update(
            params, grads, state.opt, acfg, lr_scale=lr_scale)
        out_metrics = {"loss": loss, **opt_metrics}
        return TrainState(new_params, new_opt, residual), out_metrics

    return train_step


def make_eval_step(model: Model, mesh=None):
    def eval_step(params, batch):
        loss, metrics = model.loss_fn(params, batch, mesh)
        return loss

    return eval_step
