"""Fault-tolerant training loop.

Wraps the compiled train step with the production-run machinery:
  * periodic async checkpoints (+ loader cursor in the manifest);
  * SIGTERM/SIGINT preemption hook — saves a final checkpoint and exits
    cleanly (the cluster scheduler's eviction path);
  * straggler watchdog: EWMA of step wall time; steps slower than
    `straggler_factor` x EWMA are logged with the data-loader's late-batch
    counter so operators can tell input stalls from compute stalls;
  * NaN guard: a non-finite loss aborts before the checkpoint is polluted.
"""
from __future__ import annotations

import signal
import time
from dataclasses import dataclass, field
from typing import Callable, List, Optional

import jax
import numpy as np

from repro.checkpoint import AsyncCheckpointer, latest_step, \
    restore_checkpoint
from repro.data.pipeline import LoaderState


@dataclass
class LoopConfig:
    total_steps: int = 100
    ckpt_every: int = 50
    ckpt_dir: Optional[str] = None
    log_every: int = 10
    straggler_factor: float = 3.0
    ewma_alpha: float = 0.1


@dataclass
class LoopReport:
    losses: List[float] = field(default_factory=list)
    step_times: List[float] = field(default_factory=list)
    stragglers: List[int] = field(default_factory=list)
    preempted: bool = False
    final_step: int = 0


def train_loop(state, train_step: Callable, loader, cfg: LoopConfig,
               log: Callable[[str], None] = print) -> tuple:
    """Runs `train_step(state, batch) -> (state, metrics)` for
    cfg.total_steps. Returns (state, LoopReport)."""
    report = LoopReport()
    ckpt = AsyncCheckpointer(cfg.ckpt_dir) if cfg.ckpt_dir else None
    start_step = 0

    if ckpt is not None:
        last = latest_step(cfg.ckpt_dir)
        if last is not None:
            state, extra = restore_checkpoint(cfg.ckpt_dir, last, state)
            start_step = int(extra.get("step", last))
            if hasattr(loader, "state") and "loader" in extra:
                loader.state = LoaderState.from_dict(extra["loader"])
            log(f"[resume] restored step {start_step} from {cfg.ckpt_dir}")

    preempt = {"flag": False}
    prev_handlers = {}

    def on_signal(signum, frame):
        preempt["flag"] = True

    for sig in (signal.SIGTERM, signal.SIGINT):
        try:
            prev_handlers[sig] = signal.signal(sig, on_signal)
        except ValueError:          # non-main thread (tests)
            pass

    ewma = None
    step = start_step
    try:
        while step < cfg.total_steps:
            batch = next(loader) if hasattr(loader, "__next__") \
                else loader(step)
            t0 = time.monotonic()
            state, metrics = train_step(state, batch)
            loss = float(metrics["loss"])
            dt = time.monotonic() - t0
            report.losses.append(loss)
            report.step_times.append(dt)
            if not np.isfinite(loss):
                raise FloatingPointError(f"non-finite loss at step {step}")
            if ewma is None:
                ewma = dt
            else:
                if dt > cfg.straggler_factor * ewma and step > start_step + 2:
                    late = getattr(loader, "late_batches", 0)
                    report.stragglers.append(step)
                    log(f"[straggler] step {step}: {dt:.3f}s vs EWMA "
                        f"{ewma:.3f}s (late input batches: {late})")
                ewma = (1 - cfg.ewma_alpha) * ewma + cfg.ewma_alpha * dt
            step += 1
            if cfg.log_every and step % cfg.log_every == 0:
                log(f"[train] step {step}: loss {loss:.4f} "
                    f"({dt * 1e3:.0f} ms)")
            if ckpt is not None and step % cfg.ckpt_every == 0:
                ckpt.save(step, state, extra=_extra(step, loader))
            if preempt["flag"]:
                log(f"[preempt] signal at step {step}; checkpointing")
                report.preempted = True
                break
    finally:
        if ckpt is not None:
            ckpt.wait()
            ckpt.save(step, state, extra=_extra(step, loader))
            ckpt.wait()
        for sig, h in prev_handlers.items():
            signal.signal(sig, h)
        if hasattr(loader, "close"):
            loader.close()
    report.final_step = step
    return state, report


def _extra(step: int, loader):
    extra = {"step": step}
    if hasattr(loader, "state"):
        extra["loader"] = loader.state.to_dict()
    return extra
