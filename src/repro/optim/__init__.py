from repro.optim.adamw import (AdamWConfig, init_adamw, adamw_update,
                               OptState)
from repro.optim.schedule import cosine_warmup
from repro.optim.compression import compress_grads_bf16
