"""Gradient compression with error feedback.

bf16 all-reduce halves cross-pod (DCN) gradient traffic; the f32 residual of
each cast is carried to the next step so the compression is unbiased over
time (error-feedback / EF21-style). With pjit the cast happens *before* the
psum that XLA inserts at the data/pod boundary, so the wire format is bf16.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def compress_grads_bf16(grads, residual):
    """Returns (compressed_grads_bf16, new_residual_f32).

    compressed = bf16(g + r);  new_r = (g + r) - f32(compressed)
    """
    if residual is None:
        residual = jax.tree.map(
            lambda g: jnp.zeros_like(g, dtype=jnp.float32), grads)

    def one(g, r):
        tot = g.astype(jnp.float32) + r
        q = tot.astype(jnp.bfloat16)
        return q, tot - q.astype(jnp.float32)

    flat_g, td = jax.tree.flatten(grads)
    flat_r = jax.tree.leaves(residual)
    qs, rs = zip(*(one(g, r) for g, r in zip(flat_g, flat_r)))
    return jax.tree.unflatten(td, qs), jax.tree.unflatten(td, rs)


def init_residual(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
