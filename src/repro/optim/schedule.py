"""LR schedules (pure functions of the step)."""
from __future__ import annotations

import jax.numpy as jnp


def cosine_warmup(step, *, warmup: int, total: int, floor: float = 0.1):
    """Linear warmup then cosine decay to `floor` of peak. Returns the LR
    *scale* in [0, 1] — multiply by the optimizer's peak lr."""
    step = jnp.asarray(step, jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(warmup, 1), 1.0)
    frac = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
    cos = floor + (1.0 - floor) * 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
    return warm * cos
