"""AdamW with master weights, optional compressed (bf16) moments, global-norm
clipping. Pure pytree functions — no optax dependency (built per the
'implement every substrate' brief).

Memory layout (the quantity Crispy plans for):
    stored params: RunConfig.param_dtype  (the compute copy)
    master:        f32 copy iff param_dtype != f32
    m, v:          moment_dtype (f32, or bf16 'compressed optimizer' — a
                   distributed-optimization trick that halves optimizer HBM;
                   convergence validated in tests/test_train.py)
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    moment_dtype: str = "float32"
    keep_master: bool = True      # keep f32 master if params are low-precision


class OptState(NamedTuple):
    step: jnp.ndarray
    m: dict
    v: dict
    master: Optional[dict]


def init_adamw(params, cfg: AdamWConfig) -> OptState:
    mdt = jnp.dtype(cfg.moment_dtype)
    m = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=mdt), params)
    v = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=mdt), params)
    master = None
    if cfg.keep_master and any(
            p.dtype != jnp.float32 for p in jax.tree.leaves(params)):
        master = jax.tree.map(lambda p: p.astype(jnp.float32), params)
    return OptState(jnp.zeros((), jnp.int32), m, v, master)


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(params, grads, state: OptState, cfg: AdamWConfig,
                 lr_scale=1.0):
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9)) \
        if cfg.clip_norm else 1.0
    step = state.step + 1
    t = step.astype(jnp.float32)
    bc1 = 1.0 - cfg.b1 ** t
    bc2 = 1.0 - cfg.b2 ** t
    lr = cfg.lr * lr_scale
    mdt = jnp.dtype(cfg.moment_dtype)
    source = state.master if state.master is not None else params

    def upd(p32, g, m, v):
        g = g.astype(jnp.float32) * scale
        m32 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        v32 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * g * g
        mh = m32 / bc1
        vh = v32 / bc2
        p32 = p32.astype(jnp.float32)
        new = p32 - lr * (mh / (jnp.sqrt(vh) + cfg.eps)
                          + cfg.weight_decay * p32)
        return new, m32.astype(mdt), v32.astype(mdt)

    flat_src, treedef = jax.tree.flatten(source)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.m)
    flat_v = jax.tree.leaves(state.v)
    news, ms, vs = [], [], []
    for p, g, m, v in zip(flat_src, flat_g, flat_m, flat_v):
        n, m2, v2 = upd(p, g, m, v)
        news.append(n)
        ms.append(m2)
        vs.append(v2)
    new_master_flat = news
    pdt = jax.tree.leaves(params)[0].dtype
    new_params = jax.tree.unflatten(treedef, [n.astype(pdt) for n in news])
    new_m = jax.tree.unflatten(treedef, ms)
    new_v = jax.tree.unflatten(treedef, vs)
    master = jax.tree.unflatten(treedef, new_master_flat) \
        if state.master is not None else None
    return new_params, OptState(step, new_m, new_v, master), \
        {"grad_norm": gnorm, "lr": jnp.asarray(lr)}
