"""Point acquisition: ONE cache-hierarchy + budget gate for every caller.

Before the pipeline existed, three code paths each re-implemented "get a
profile point without paying twice": the AllocationService (LRU -> store
-> fresh run), the AdaptiveLadderScheduler's `take()`, and the
ProfilingExecutor's `one()`. They mostly agreed — but the one-shot
CrispyAllocator path never refreshed its ProfileStore, so points a
sibling process had already profiled (and charged to a shared
ProfilingBudget envelope) were invisible, re-profiled, and charged a
second time. `PointSource` is now the only implementation of the rule:

  peek (LRU, then shared store — refreshed once per acquisition) is
  consulted BEFORE the budget gate, so cached work is always free;
  only a genuinely fresh run reserves a budget point and charges its
  reported wall seconds; a reservation that races another thread's
  fresh run is refunded, never charged.

Callers plug in at the edges: an optional `cache` (get/put, e.g. the
service's LRU), an optional `store` (repro.profiling.ProfileStore), an
optional `budget`, and counter hooks for service stats.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import Callable, Optional, Tuple

from repro.core.profiler import ProfileResult
from repro.telemetry import default_registry


@dataclass
class AcquisitionStats:
    """Counters one acquisition run accumulates (feeds PipelineTrace)."""
    fresh: int = 0               # profile runs actually executed
    cache_hits: int = 0          # points served by LRU or store
    store_hits: int = 0          # subset of cache_hits served by the store
    denied: bool = False         # the budget refused at least one point


class PointSource:
    """Budget-gated, cache-backed access to `profile_at(size)` for one
    job signature. Thread-safe: fixed ladders fan points over a
    ProfilingExecutor pool through one instance."""

    def __init__(self, signature: str,
                 profile_at: Callable[[float], ProfileResult],
                 budget=None,                 # repro.profiling ProfilingBudget
                 store=None,                  # repro.profiling ProfileStore
                 cache=None,                  # object with get/put (LRU view)
                 refresh_store: bool = True,
                 telemetry=None):             # repro.telemetry MetricsRegistry
        self.signature = signature
        self.profile_at = profile_at
        self.budget = budget
        self.store = store
        self.cache = cache
        self.stats = AcquisitionStats()
        self._lock = threading.Lock()
        # process-wide acquisition-tier heat (stats above is per-plan)
        tel = telemetry if telemetry is not None else default_registry()
        self._c_fresh = tel.counter("acquisition.fresh")
        self._c_lru = tel.counter("acquisition.lru_hits")
        self._c_store = tel.counter("acquisition.store_hits")
        self._c_denied = tel.counter("acquisition.denied")
        # reported profile cost (the paper's envelope currency), not the
        # simulator's real microseconds — matches what budgets charge
        self._h_profile = tel.histogram("acquisition.profile_seconds")
        if store is not None and refresh_store:
            try:
                # pull sibling processes' points in BEFORE planning: a
                # point any process already profiled must be served free,
                # not re-measured and double-charged to a shared envelope
                store.refresh()
            except Exception:
                pass                         # a stale view is still correct

    # -- cache hierarchy ----------------------------------------------------
    def peek(self, size: float) -> Optional[ProfileResult]:
        """LRU then shared store; no profiling, no budget interaction.
        Does NOT count hits (acquire() does) — safe for budget gates and
        schedulers to call speculatively."""
        if self.cache is not None:
            r = self.cache.get(self.signature, size)
            if r is not None:
                return r
        if self.store is not None:
            r = self.store.get(self.signature, size)
            if r is not None:
                if self.cache is not None:
                    self.cache.put(self.signature, size, r, from_store=True)
                return r
        return None

    def _record_hit(self, from_store: bool) -> None:
        with self._lock:
            self.stats.cache_hits += 1
            if from_store:
                self.stats.store_hits += 1
        (self._c_store if from_store else self._c_lru).inc()

    # -- the one acquisition rule -------------------------------------------
    def acquire(self, size: float) -> Optional[Tuple[ProfileResult, bool]]:
        """One point through the hierarchy: `(result, fresh)`, or None
        when the budget denied a fresh run. Cached points are free by
        construction — they are served before the budget is consulted."""
        if self.cache is not None:
            r = self.cache.get(self.signature, size)
            if r is not None:
                self._record_hit(from_store=False)
                return r, False
        if self.store is not None:
            r = self.store.get(self.signature, size)
            if r is not None:
                if self.cache is not None:
                    self.cache.put(self.signature, size, r, from_store=True)
                self._record_hit(from_store=True)
                return r, False
        if self.budget is not None and not self.budget.try_spend():
            with self._lock:
                self.stats.denied = True
            self._c_denied.inc()
            return None
        # a sibling thread may have profiled this size between the peek
        # and the reservation: re-check the cache so the run (and its
        # charge) never happens twice
        if self.cache is not None:
            r = self.cache.get(self.signature, size)
            if r is not None:
                if self.budget is not None:
                    self.budget.refund()
                self._record_hit(from_store=False)
                return r, False
        try:
            r = self.profile_at(size)
        except BaseException:
            # a failing profile run must hand its reservation back: with
            # a shared max_points envelope, leaked reservations from
            # transient profiler crashes would drain the budget without a
            # single point measured
            if self.budget is not None:
                self.budget.refund()
            raise
        if self.budget is not None:
            self.budget.charge(r.wall_s)
        with self._lock:
            self.stats.fresh += 1
        self._c_fresh.inc()
        self._h_profile.observe(r.wall_s)
        if self.cache is not None:
            self.cache.put(self.signature, size, r, from_store=False)
        if self.store is not None:
            try:
                self.store.put(self.signature, size, r)
            except Exception:
                pass            # a write-through failure costs a future
                                # re-profile, never this plan
        return r, True

    # -- legacy ProfilePointFn adapter --------------------------------------
    def as_point_fn(self):
        """The `(size) -> (result, fresh)` callable (with `.peek`) the
        PR-2 scheduler/executor interfaces expect, WITHOUT their budget
        handling: this source already gates and charges, so callers must
        not pass a budget of their own alongside it."""
        def pp(size: float) -> Tuple[ProfileResult, bool]:
            got = self.acquire(size)
            if got is None:
                from repro.profiling.budget import BudgetExhausted
                raise BudgetExhausted(
                    f"budget denied point {size!r} for {self.signature!r}")
            return got
        pp.peek = self.peek
        return pp


@dataclass
class MemoryPointCache:
    """Minimal `cache=` adapter for embedders and tests that want a
    process-local point cache without a service LRU: a plain dict, no
    eviction. (The one-shot CrispyAllocator path runs cache-less on
    purpose — placers never re-request a measured size, and its shared
    reuse goes through `store=`.)"""
    _points: dict = field(default_factory=dict)

    def get(self, signature: str, size: float) -> Optional[ProfileResult]:
        return self._points.get((signature, float(size)))

    def put(self, signature: str, size: float, result: ProfileResult,
            from_store: bool = False) -> None:
        self._points[(signature, float(size))] = result
