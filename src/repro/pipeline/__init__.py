"""Unified allocation pipeline: ONE staged decision path for every caller.

The paper's core loop (§III-A: sample -> profile -> model -> select) used
to exist twice — once in `CrispyAllocator.allocate` and once, with
diverging cache/store/budget semantics, inside `AllocationService`. This
package is now the only implementation; everything else is an entry point
that builds requests and reports around it:

                         PipelineRequest
                               |
        +----------------------v----------------------+
        | 1  warm-start lookup                        |
        |    registry.get(sig) -> confident model?    |--yes--> stage 5
        +----------------------+----------------------+
                               | no
        +----------------------v----------------------+
        | 2  point acquisition        (acquisition.py)|
        |    PointSource: LRU -> shared ProfileStore  |
        |    -> fresh profile run; cached points are  |
        |    NEVER budget-charged; ladder from anchor |
        |    (given > store > 1% of full size)        |
        |    placement when adaptive   (placement.py) |
        |      "infogain" (default): next size =      |
        |        argmax expected reduction in         |
        |        candidate disagreement at full_size, |
        |        cost-aware: among informative sizes  |
        |        prefer the cheapest predicted wall   |
        |      "ladder": smallest-first prefix +      |
        |        gap-midpoint escalation (PR-2)       |
        +----------------------+----------------------+
                               |
        +----------------------v----------------------+
        | 3  model fitting                            |
        |    fitter / model zoo (LOOCV selection)     |
        | 3b runtime companion fit    (fit_runtime_   |
        |    zoo over the ladder's wall times; its    |
        |    own relaxed gate, R2>0.95 + LOOCV<=0.10) |
        +----------------------+----------------------+
                               |
        +----------------------v----------------------+
        | 4  gate + fallback chain                    |
        |    classifier.observe (always)              |
        |    confident -> register + serve "zoo"      |
        |    (confident runtime fit registered too)   |
        |    else nearest-job transfer ("classifier") |
        |    else requirement 0 ("baseline" == BFA)   |
        +----------------------+----------------------+
                               |            (per request, plans are shared
        +----------------------v----------+  by coalesced signature groups)
        | 5  requirement extrapolation    |
        |    model.requirement(full_size, |
        |                      leeway)    |
        +----------------------+----------+
                               |
        +----------------------v----------+
        | 6  config selection             |
        |    select_crispy / neighbor's   |
        |    best config / BFA            |
        |    objective axis (request):    |
        |      cheapest_fit (default,     |
        |        the paper, bit-exact)    |
        |      min_cost / min_runtime:    |
        |        Pareto front over        |
        |        ($/h x predicted wall,   |
        |        wall); degrade to        |
        |        cheapest_fit whenever    |
        |        the runtime fit is       |
        |        unconfident              |
        +----------------------+----------+
                               |
                         PipelineTrace
                          /          \
                 CrispyReport    AllocationResponse
               (core/crispy.py) (allocator/service.py)

Entry points driving the pipeline:

  * `CrispyAllocator.allocate` (core/crispy.py) — thin one-shot wrapper;
  * `AllocationService` (allocator/service.py) — batching, coalescing,
    futures, LRU and plan caches, wire stats: CONCURRENCY ONLY, no
    ladder/fit/selection logic of its own (tests/test_allocator.py pins
    this with a parity contract: service and one-shot answers over the
    same backend are byte-identical);
  * `examples/profile_and_select.py`, `benchmarks/point_placement.py` —
    direct `AllocationPipeline.run()` users.

Shared state composes exactly as before: `store=` (ProfileStore over any
repro.state backend), `budget=` (ProfilingBudget, shared-envelope aware),
`executor=` (ProfilingExecutor for fixed-ladder point concurrency),
`registry=`/`classifier=` for warm starts and Flora-style transfer.

Telemetry (repro.telemetry; `telemetry=` overrides the process default):

  stage 1      hist  pipeline.stage.warm_start.seconds (sampled 1-in-8*)
               ctrs  pipeline.warm_start.{hits,misses}        (exact)
  stage 2      hist  pipeline.stage.acquire.seconds           (always)
               ctrs  acquisition.{fresh,lru_hits,store_hits,denied}
               hist  acquisition.profile_seconds   (PointSource; exact)
               ctrs  budget.{reserved_points,refunded_points,
                     charged_seconds,denials}   (ProfilingBudget; exact)
  stage 3      hist  pipeline.stage.fit.seconds               (always)
  stage 4      hist  pipeline.stage.classify.seconds          (always)
  stages 5-6   hist  pipeline.stage.{extrapolate,select}.seconds
                     (sampled 1-in-8*)

* the resting rate. `sampler=` picks the warm-path sampling policy
(repro.telemetry.sampling): None/"fixed"/int keep a constant mask,
"adaptive" raises the rate toward 1-in-1 while warm-stage windowed p99
drifts past its gate and decays it back after recovery.

Spans (`pipeline.<stage>`) open on the cold path always, on the warm
path only when nested inside a caller's span; exact per-request walls
always land on `PipelinePlan.stage_walls` -> `PipelineTrace.stage_walls`
(opt-in on the wire via `AllocationEndpoint.handle(include_trace=True)`).
See repro/telemetry/__init__.py for the full observability map.
"""
from repro.pipeline.acquisition import (AcquisitionStats, MemoryPointCache,
                                        PointSource)
from repro.pipeline.pipeline import (AllocationPipeline, GiB, PipelinePlan,
                                     PipelineRequest, PipelineTrace)
from repro.pipeline.placement import (DISAGREE_RTOL, InfoGainPlacer,
                                      LadderPlacer, MAX_EXTRA_POINTS,
                                      MIN_POINTS, PLACEMENTS,
                                      PlacementOutcome, PlacementState,
                                      PointPlacer, STABILITY_RTOL,
                                      candidate_disagreement,
                                      drive_placement, gap_midpoints,
                                      make_placer, prediction_spread)

__all__ = [
    "AcquisitionStats", "AllocationPipeline", "DISAGREE_RTOL", "GiB",
    "InfoGainPlacer", "LadderPlacer", "MAX_EXTRA_POINTS",
    "MemoryPointCache", "MIN_POINTS", "PLACEMENTS", "PipelinePlan",
    "PipelineRequest", "PipelineTrace", "PlacementOutcome",
    "PlacementState", "PointPlacer", "PointSource", "STABILITY_RTOL",
    "candidate_disagreement", "drive_placement", "gap_midpoints",
    "make_placer", "prediction_spread",
]
