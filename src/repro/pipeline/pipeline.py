"""AllocationPipeline: the paper's loop as ONE staged decision path.

See the package docstring (`repro/pipeline/__init__.py`) for the stage
diagram. `AllocationPipeline.plan()` runs the per-signature stages
(warm-start, acquisition, fitting, fallback classification);
`finalize()` runs the per-request stages (requirement extrapolation,
config selection) and returns a `PipelineTrace` — the one record both
`CrispyReport` (core/crispy.py) and `AllocationResponse`
(allocator/service.py) are built from. `run()` composes the two for
one-shot callers.

Telemetry (repro.telemetry): stages record wall histograms
(`pipeline.stage.<stage>.seconds`) and spans (`pipeline.<stage>`) into
the pipeline's `MetricsRegistry` (the process default unless
`telemetry=` overrides it). Cold stages (acquire/fit/classify) always
record; warm stages (warm_start/extrapolate/select) sample their
histograms 1-in-8 and open spans only when nested inside a caller span
— see the `_sample_mask` comment in `__init__` for the economics.
Exact per-request stage walls always land on `PipelinePlan.stage_walls`
/ `PipelineTrace.stage_walls` so a single decision can be broken down
after the fact. Acquisition-tier heat (LRU/store/fresh/denied) is
counted by `PointSource`.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from time import perf_counter
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.allocator.model_zoo import fit_runtime_zoo, fit_zoo
from repro.telemetry import (current_span, default_registry,
                             resolve_sampler, span_if)
from repro.core.catalog import ClusterConfig
from repro.core.history import ExecutionHistory
from repro.core.profiler import ProfileResult
from repro.core.sampling import ladder_from_anchor
from repro.core.selector import (DEFAULT_OVERHEAD_GIB, Selection,
                                 select_crispy, select_like)
from repro.pipeline.acquisition import PointSource
from repro.pipeline.placement import drive_placement, make_placer

GiB = 1024 ** 3


@dataclass
class PipelineRequest:
    """One allocation question, backend-agnostic: everything the staged
    path needs to answer 'how much memory, which config'."""
    job: str
    profile_at: Callable[[float], ProfileResult]
    full_size: float
    anchor: Optional[float] = None
    sizes: Optional[Sequence[float]] = None
    signature: Optional[str] = None     # defaults to the job name
    leeway: Optional[float] = None      # overrides the pipeline default
    adaptive: Optional[bool] = None     # overrides the pipeline default
    placement: Optional[object] = None  # "infogain" | "ladder" | PointPlacer
    exclude_job_in_history: bool = True
    tags: Optional[Sequence[str]] = None    # Flora-style categorical tags
    objective: str = "cheapest_fit"     # | "min_cost" | "min_runtime"

    @property
    def sig(self) -> str:
        return self.signature if self.signature is not None else self.job


@dataclass
class PipelinePlan:
    """Per-signature outcome of stages 1-4; shared by every request that
    coalesced onto the same (signature, ladder)."""
    signature: str
    source: str                      # registry | zoo | classifier | baseline
    model: Optional[object]          # the SERVING model (None on baseline)
    candidate: Optional[str]         # winning model kind (None on baseline)
    fit: Optional[object] = None     # this job's own fit (unconfident ones
                                     # still reach CrispyReport.model)
    runtime_fit: Optional[object] = None   # runtime companion model (a
                                     # RuntimeFit, or the bare registered
                                     # model on warm starts); feeds the
                                     # min_cost/min_runtime objectives
    runtime_candidate: Optional[str] = None
    neighbor: Optional[str] = None
    neighbor_selection: Optional[Selection] = None
    sizes: List[float] = field(default_factory=list)
    mems: List[float] = field(default_factory=list)
    walls: List[float] = field(default_factory=list)
    results: List[ProfileResult] = field(default_factory=list)
    requirement_trace: List[float] = field(default_factory=list)
    profiled: int = 0                # fresh profile_at calls
    cache_hits: int = 0              # points served by LRU or shared store
    store_hits: int = 0              # subset served by the shared store
    adaptive: bool = False
    placement: Optional[str] = None  # placer name when adaptive
    early_stop: bool = False
    escalated: bool = False
    budget_exhausted: bool = False
    base_points: int = 0             # base-ladder length (points_saved basis)
    fit_ran: bool = False            # a zoo/fitter fit happened
    registered: bool = False         # a confident model was registered
    newly_observed: bool = False     # first time the classifier saw this sig
    stage_walls: Dict[str, float] = field(default_factory=dict)
    # per-stage wall seconds for THIS plan (warm_start | acquire | fit |
    # classify); finalize() adds the per-request stages on the trace

    @property
    def total_points(self) -> int:
        return len(self.sizes)


@dataclass
class PipelineTrace:
    """One finished decision: the shared plan plus this request's
    extrapolation and selection — the single record CrispyReport and
    AllocationResponse are both built from."""
    plan: PipelinePlan
    job: str
    full_size: float
    requirement_gib: float
    selection: Selection
    wall_s: float = 0.0
    stage_walls: Dict[str, float] = field(default_factory=dict)
    # plan stages + this request's extrapolate/select walls (seconds)

    # convenience proxies (report builders read these off the trace)
    @property
    def sizes(self) -> List[float]:
        return self.plan.sizes

    @property
    def mems(self) -> List[float]:
        return self.plan.mems

    @property
    def results(self) -> List[ProfileResult]:
        return self.plan.results

    @property
    def source(self) -> str:
        return self.plan.source


class AllocationPipeline:
    """The one staged decision path (see package docstring). Thread-safe:
    concurrent signature groups may call `plan()` simultaneously (the
    AllocationService fans them over a ProfilingExecutor)."""

    def __init__(self, catalog: List[ClusterConfig],
                 history: ExecutionHistory,
                 registry=None,             # allocator ModelRegistry (or None)
                 classifier=None,           # NearestJobClassifier (or None)
                 fitter: Optional[Callable] = None,
                 candidates: Optional[Sequence] = None,
                 runtime_fitter: Optional[Callable] = None,
                 overhead_per_node_gib: float = DEFAULT_OVERHEAD_GIB,
                 leeway: float = 0.0,
                 adaptive: bool = False,
                 placement="infogain",
                 budget=None,               # repro.profiling ProfilingBudget
                 store=None,                # repro.profiling ProfileStore
                 executor=None,             # repro.profiling ProfilingExecutor
                 cache=None,                # LRU adapter (get/put), optional
                 defer_registry_save: bool = False,
                 refresh_store: bool = True,
                 telemetry=None,            # repro.telemetry MetricsRegistry
                 sampler=None):             # None|"adaptive"|"fixed"|int|obj
        # refresh_store=False is for callers that already refresh the
        # shared store on their own cadence (the AllocationService does it
        # once per batch); everyone else must see sibling points before
        # planning, or re-profile — and double-charge a shared budget
        # envelope for — work that is already stored.
        self.catalog = catalog
        self.history = history
        self.registry = registry
        self.classifier = classifier
        self.fitter = fitter
        self.candidates = candidates
        self.runtime_fitter = runtime_fitter
        self.overhead = overhead_per_node_gib
        self.leeway = leeway
        self.adaptive = adaptive
        self.placement = placement
        self.budget = budget
        self.store = store
        self.executor = executor
        self.cache = cache
        self.defer_registry_save = defer_registry_save
        self.refresh_store = refresh_store
        self._lock = threading.Lock()       # guards the classifier
        self.telemetry = telemetry if telemetry is not None \
            else default_registry()
        # instruments are created once here, not per plan: the factory
        # takes the registry lock, the hot path must not
        self._stage_hist = {
            s: self.telemetry.histogram(f"pipeline.stage.{s}.seconds")
            for s in ("warm_start", "acquire", "fit", "classify",
                      "extrapolate", "select")}
        self._warm_hits = self.telemetry.counter("pipeline.warm_start.hits")
        self._warm_misses = self.telemetry.counter(
            "pipeline.warm_start.misses")
        # warm-path economics: a registry hit answers in tens of µs, so
        # per-request spans (or even an unconditional histogram observe)
        # would blow the <5% overhead pin. Warm-path stage histograms are
        # sampled 1-in-(mask+1); warm-path spans exist only when nested
        # inside an active caller span. Counters stay exact. The cold
        # path (acquire/fit) always records — profiling dwarfs it.
        # The mask comes from a sampler (repro.telemetry.sampling):
        # FixedSampler(7) by default, or AdaptiveSampler — which raises
        # the rate toward 1-in-1 while warm-stage windowed p99 drifts
        # past its gate — via sampler="adaptive". tick() is called only
        # on sampled iterations and is interval-gated inside.
        self.sampler = resolve_sampler(sampler, self.telemetry)
        self._sample_mask = self.sampler.mask
        self._sample_n = 0      # benign races: a lost bump skews sampling

    # -- stage 2a: ladder resolution ----------------------------------------
    def ladder_for(self, req: PipelineRequest) -> Tuple[float, ...]:
        """The base ladder this request profiles over: explicit sizes win;
        otherwise the anchor (given > store-persisted > 1% of full size)
        shapes the paper's 5-point ladder. An explicit anchor is written
        back to the store so sibling processes skip anchor guessing."""
        if req.sizes is not None:
            return tuple(float(s) for s in req.sizes)
        anchor = req.anchor
        if anchor is None and self.store is not None:
            anchor = self.store.get_anchor(req.sig)
        if anchor is None:
            anchor = req.full_size * 0.01
        elif req.anchor is not None and self.store is not None \
                and self.store.get_anchor(req.sig) is None:
            try:
                self.store.put_anchor(req.sig, float(req.anchor))
            except Exception:
                pass        # a failed anchor write must never fail the plan
        return tuple(float(s) for s in ladder_from_anchor(anchor).sizes)

    # -- stage 3: model fitting ---------------------------------------------
    def _fit(self, sizes: Sequence[float], mems: Sequence[float]):
        if self.fitter is not None:
            return self.fitter(sizes, mems)
        return fit_zoo(sizes, mems, self.candidates)

    def _fit_runtime(self, sizes: Sequence[float], walls: Sequence[float]):
        if self.runtime_fitter is not None:
            return self.runtime_fitter(sizes, walls)
        return fit_runtime_zoo(sizes, walls)

    # -- stage 1: warm start ------------------------------------------------
    def warm_start(self, signature: str) -> Optional[PipelinePlan]:
        """A confident registered model answers without any profiling."""
        t0 = perf_counter()
        plan = None
        with span_if(self.telemetry.enabled
                     and current_span() is not None,
                     "pipeline.warm_start", signature=signature):
            if self.registry is not None:
                rec = self.registry.get(signature)
                if rec is not None and getattr(rec.model, "confident",
                                               False):
                    plan = PipelinePlan(
                        signature, "registry", rec.model, rec.candidate,
                        runtime_fit=rec.runtime_model,
                        runtime_candidate=rec.runtime_candidate)
        wall = perf_counter() - t0
        if plan is not None:
            self._warm_hits.inc()
            plan.stage_walls["warm_start"] = wall
        else:
            self._warm_misses.inc()
        self._sample_n = n = (self._sample_n + 1) & self._sample_mask
        if not n:
            self._stage_hist["warm_start"].observe(wall)
            self._sample_mask = self.sampler.tick()
        return plan

    # -- stages 1-4: per-signature plan -------------------------------------
    def plan(self, req: PipelineRequest,
             ladder: Optional[Sequence[float]] = None) -> PipelinePlan:
        warm = self.warm_start(req.sig)
        if warm is not None:
            return warm
        return self.measure_plan(req, ladder)

    # -- stages 2-4: profile, fit, fall back --------------------------------
    def measure_plan(self, req: PipelineRequest,
                     ladder: Optional[Sequence[float]] = None
                     ) -> PipelinePlan:
        sig = req.sig
        tel = self.telemetry
        # stage 2: point acquisition through the one budgeted cache
        # hierarchy (LRU -> shared store -> fresh run)
        base = list(ladder if ladder is not None else self.ladder_for(req))
        source = PointSource(sig, req.profile_at, budget=self.budget,
                             store=self.store, cache=self.cache,
                             refresh_store=self.refresh_store,
                             telemetry=tel)
        adaptive = req.adaptive if req.adaptive is not None else self.adaptive

        # adaptive placement interleaves fitting with acquisition inside
        # drive_placement, so the fit wall is accumulated through this
        # wrapper and subtracted from the acquisition elapsed time —
        # stage walls stay disjoint either way
        fit_wall = [0.0]

        def timed_fit(sizes, mems):
            t0 = perf_counter()
            try:
                return self._fit(sizes, mems)
            finally:
                fit_wall[0] += perf_counter() - t0

        t_acq = perf_counter()
        if adaptive:
            placer = make_placer(req.placement if req.placement is not None
                                 else self.placement)
            with span_if(tel.enabled, "pipeline.acquire", signature=sig,
                         adaptive=True):
                out = drive_placement(placer, base, req.full_size,
                                      source.acquire, timed_fit)
            sizes, mems, results, fit = out.sizes, out.mems, out.results, \
                out.fit
            flags = (out.early_stop, out.escalated, out.budget_exhausted)
            placement_name = getattr(placer, "name", None)
            trace = out.requirement_trace
        else:
            with span_if(tel.enabled, "pipeline.acquire", signature=sig,
                         adaptive=False):
                sizes, mems, results, exhausted = self._acquire_fixed(
                    source, base)
            with span_if(tel.enabled, "pipeline.fit", signature=sig):
                fit = timed_fit(sizes, mems)
            flags = (False, False, exhausted)
            placement_name = None
            trace = []
        acquire_wall = max(0.0, perf_counter() - t_acq - fit_wall[0])
        walls = [r.wall_s for r in results]

        # stage 3b: runtime companion fit over the same ladder's wall
        # times — the min_cost/min_runtime objectives rank feasible
        # configs by it at selection time (charged to the fit stage)
        runtime_fit = None
        if len(sizes) >= 2 and len(walls) == len(sizes):
            t_rt = perf_counter()
            with span_if(tel.enabled, "pipeline.fit_runtime",
                         signature=sig):
                runtime_fit = self._fit_runtime(sizes, walls)
            fit_wall[0] += perf_counter() - t_rt
        self._stage_hist["acquire"].observe(acquire_wall)
        self._stage_hist["fit"].observe(fit_wall[0])

        # stage 4a: every profiled ladder feeds future classifications,
        # gate-failing ones included
        newly_observed = False
        classify_wall = 0.0
        if self.classifier is not None:
            t_cls = perf_counter()
            with self._lock:
                newly_observed = not self.classifier.has(sig)
                self.classifier.observe(sig, sizes, mems, walls,
                                        tags=req.tags)
            classify_wall += perf_counter() - t_cls

        plan = PipelinePlan(sig, "baseline", None, None, fit=fit,
                            runtime_fit=runtime_fit,
                            runtime_candidate=getattr(runtime_fit,
                                                      "candidate", None),
                            sizes=list(sizes), mems=list(mems), walls=walls,
                            results=list(results), requirement_trace=trace,
                            profiled=source.stats.fresh,
                            cache_hits=source.stats.cache_hits,
                            store_hits=source.stats.store_hits,
                            adaptive=adaptive, placement=placement_name,
                            early_stop=flags[0], escalated=flags[1],
                            budget_exhausted=flags[2],
                            base_points=len(base), fit_ran=True,
                            newly_observed=newly_observed)
        plan.stage_walls["acquire"] = acquire_wall
        plan.stage_walls["fit"] = fit_wall[0]

        # stage 4b: confident fit -> serve and register it
        resolved = False
        if getattr(fit, "confident", False):
            model = getattr(fit, "model", fit)
            candidate = getattr(fit, "candidate",
                                getattr(fit, "kind", "linear"))
            plan.source, plan.model, plan.candidate = "zoo", fit, candidate
            if self.registry is not None:
                rt_ok = getattr(runtime_fit, "confident", False)
                self.registry.put(
                    sig, model, candidate, sizes, mems,
                    defer_save=self.defer_registry_save,
                    runtime_model=getattr(runtime_fit, "model",
                                          runtime_fit) if rt_ok else None,
                    runtime_candidate=getattr(runtime_fit, "candidate",
                                              None) if rt_ok else None,
                    walls=walls)
                plan.registered = True
            resolved = True

        # stage 4c: unconfident -> nearest-neighbor transfer (Flora)
        if not resolved and self.classifier is not None and len(sizes) >= 2:
            t_cls = perf_counter()
            with self._lock:
                cls = self.classifier.classify(sizes, mems, walls,
                                               exclude=(sig,),
                                               tags=req.tags)
            classify_wall += perf_counter() - t_cls
            if cls is not None:
                neighbor_rec = self.registry.get(cls.neighbor,
                                                 count_hit=False) \
                    if self.registry is not None else None
                if neighbor_rec is not None and \
                        getattr(neighbor_rec.model, "confident", False):
                    plan.source = "classifier"
                    plan.model = neighbor_rec.model
                    plan.candidate = neighbor_rec.candidate
                    plan.neighbor = cls.neighbor
                else:
                    sel = select_like(self.catalog, self.history,
                                      cls.neighbor)
                    if sel is not None:
                        plan.source = "classifier"
                        plan.neighbor = cls.neighbor
                        plan.neighbor_selection = sel
        # stage 4d: baseline (requirement 0 == exactly BFA, the paper's
        # never-worse-than-fallback property): plan.source is still
        # "baseline" when neither 4b nor 4c claimed the plan above
        self._stage_hist["classify"].observe(classify_wall)
        plan.stage_walls["classify"] = classify_wall
        return plan

    def _acquire_fixed(self, source: PointSource,
                       sizes: Sequence[float]):
        """Fixed-ladder acquisition: every point, concurrently when an
        executor is configured; budget denials leave holes and the fit
        runs over whatever materialized."""
        if self.executor is not None and len(sizes) > 1:
            rows = self.executor.map_tasks(source.acquire, list(sizes))
        else:
            rows = [source.acquire(s) for s in sizes]
        used = [s for s, rf in zip(sizes, rows) if rf is not None]
        results = [rf[0] for rf in rows if rf is not None]
        mems = [r.job_mem_bytes for r in results]
        return used, mems, results, any(rf is None for rf in rows)

    # -- stages 5-6: per-request finalization -------------------------------
    def finalize(self, plan: PipelinePlan, req: PipelineRequest,
                 wall_s: float = 0.0) -> PipelineTrace:
        """Requirement extrapolation + config selection for one request
        over a (possibly shared) plan."""
        leeway = req.leeway if req.leeway is not None else self.leeway
        exclude = req.job if req.exclude_job_in_history else None
        nested = self.telemetry.enabled and current_span() is not None
        t0 = perf_counter()
        with span_if(nested, "pipeline.extrapolate", job=req.job,
                     source=plan.source):
            if plan.model is not None:
                req_gib = plan.model.requirement(req.full_size,
                                                 leeway) / GiB
                sel = None
            elif plan.neighbor_selection is not None:
                req_gib = 0.0
                sel = plan.neighbor_selection
            else:
                req_gib = 0.0
                sel = None
        t_extra = perf_counter()
        if sel is None:
            with span_if(nested, "pipeline.select", job=req.job):
                sel = select_crispy(self.catalog, self.history, req_gib,
                                    overhead_per_node_gib=self.overhead,
                                    exclude_job=exclude,
                                    objective=req.objective,
                                    runtime_model=plan.runtime_fit,
                                    full_size=req.full_size)
        t_sel = perf_counter()
        self._sample_n = n = (self._sample_n + 1) & self._sample_mask
        if not n:
            self._stage_hist["extrapolate"].observe(t_extra - t0)
            self._stage_hist["select"].observe(t_sel - t_extra)
            self._sample_mask = self.sampler.tick()
        trace = PipelineTrace(plan, req.job, req.full_size, req_gib, sel,
                              wall_s)
        trace.stage_walls = dict(plan.stage_walls)
        trace.stage_walls["extrapolate"] = t_extra - t0
        trace.stage_walls["select"] = t_sel - t_extra
        return trace

    def run(self, req: PipelineRequest) -> PipelineTrace:
        """The whole staged path for one request (the one-shot and
        example/benchmark entry point)."""
        t0 = time.monotonic()
        plan = self.plan(req)
        return self.finalize(plan, req, time.monotonic() - t0)
