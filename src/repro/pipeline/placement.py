"""Point placement: which sample size to profile next, and when to stop.

The paper profiles a fixed five-point ladder. PR 2 made the *count*
adaptive (walk the ladder smallest-first, stop once the fit is confident
and stable, escalate into the widest gaps when candidates disagree) but
the *positions* stayed ladder-bound. This module makes placement itself a
strategy behind one protocol:

  LadderPlacer    the PR-2 semantics: smallest-first prefix of the base
                  ladder, early stop on confident+stable, gap-midpoint
                  escalation entered only when the zoo's candidates
                  disagree about the full-size prediction (and run to
                  confidence or the cap once entered). Midpoints are
                  recomputed from the measured sizes per step — identical
                  to the precomputed PR-2 list on equally spaced ladders.

  InfoGainPlacer  information-optimal placement (the default). After two
                  cheap seed points, every unmeasured candidate size is
                  scored by the *expected reduction in candidate-model
                  disagreement at full_size*: each fitted zoo candidate is
                  taken in turn as the truth hypothesis, the candidate
                  pool is refit as if the point had been measured under
                  that hypothesis, and the spread of the refit full-size
                  predictions is averaged over hypotheses. The argmax
                  size is profiled next; placement stops when the best
                  expected gain falls below the stability threshold (more
                  measurement would not change the answer), or the fit is
                  confident and stable, as with the ladder. Single-model
                  (non-zoo) fitters have nothing to rank, so they get
                  full ladder semantics — same points, same cost.

Why it wins on curved jobs: a smallest-first prefix clusters measurements
at the cheap end of the ladder, exactly where a power-law or piecewise
curve is least distinguishable from a line, so the prefix must run long
(or escalate) before the models separate. Disagreement-driven placement
jumps straight to the sizes where the hypotheses diverge — usually the
far end of the calibrated range — and separates the candidates in fewer
points (benchmarks/point_placement.py measures this; Ruya,
arXiv:2211.04240, motivates memory-aware iterative search over fixed
ladders).

Both placers only ever propose sizes inside [min(ladder), max(ladder)]:
the anchor was calibrated so the largest ladder point stays in the
paper's per-run wall-time band, and placement must not silently leave it.

The driving loop lives in `repro.pipeline.pipeline.AllocationPipeline`
(the acquisition stage); placers are pure decision objects and never
profile anything themselves.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import List, Optional, Protocol, Sequence

from repro.allocator.model_zoo import ZooFit
from repro.core.memory_model import LinearMemoryModel, fit_memory_model

MIN_POINTS = 3              # LOOCV needs 3; stability needs a predecessor
STABILITY_RTOL = 0.05       # requirement prediction settled within 5%
DISAGREE_RTOL = 0.25        # candidate spread that justifies extra points
MAX_EXTRA_POINTS = 2        # extra spend beyond the base ladder, either placer

PLACEMENTS = ("infogain", "ladder")


@dataclass
class PlacementState:
    """What a placer may look at when proposing the next size: the base
    ladder, everything measured so far, and the latest (re)fit."""
    ladder: List[float]              # base ladder, ascending
    full_size: float
    sizes: List[float] = field(default_factory=list)
    mems: List[float] = field(default_factory=list)
    walls: List[float] = field(default_factory=list)   # per-point wall s
    fit: Optional[object] = None     # ZooFit (or custom fitter output)
    stable: bool = False             # last two requirement predictions agree

    @property
    def measured(self) -> set:
        return set(self.sizes)

    @property
    def beyond_base(self) -> int:
        """Points spent past the base-ladder length (escalation depth)."""
        return max(0, len(self.sizes) - len(self.ladder))


class PointPlacer(Protocol):
    """Strategy protocol: propose the next sample size, or None to stop.
    Implementations must be stateless across runs (one placer instance
    serves many signatures); all run state arrives via PlacementState."""

    name: str

    def next_size(self, state: PlacementState) -> Optional[float]: ...


def _confident(fit: object) -> bool:
    return bool(getattr(fit, "confident", False))


def prediction_spread(fits: dict, full_size: float) -> float:
    """Relative spread of a candidate set's full-size predictions
    (non-finite predictions dropped; < 2 finite answers spread 0)."""
    preds = []
    for m in fits.values():
        try:
            p = float(m.predict(full_size))
        except (OverflowError, ValueError):
            p = math.inf
        if math.isfinite(p):
            preds.append(p)
    if len(preds) < 2:
        return 0.0
    lo, hi = min(preds), max(preds)
    scale = max(abs(hi), abs(lo), 1e-12)
    return (hi - lo) / scale


def candidate_disagreement(fit: object, full_size: float) -> float:
    """Relative spread of the zoo candidates' full-size predictions — the
    quantity both placers treat as 'how unsettled is the answer'. A
    non-zoo (single-model) fit disagrees with itself only through
    non-confidence."""
    if not isinstance(fit, ZooFit):
        return math.inf if not _confident(fit) else 0.0
    return prediction_spread(fit.fits or {}, full_size)


def gap_midpoints(sizes: Sequence[float], n: int) -> List[float]:
    """Midpoints of the `n` widest gaps between measured sizes —
    densification candidates inside the calibrated range."""
    xs = sorted(set(sizes))
    if len(xs) < 2 or n <= 0:
        return []
    gaps = sorted(((xs[i + 1] - xs[i], 0.5 * (xs[i] + xs[i + 1]))
                   for i in range(len(xs) - 1)), reverse=True)
    return [mid for _gap, mid in gaps[:n]]


class LadderPlacer:
    """PR-2 semantics as a placement strategy: the smallest-first ladder
    prefix with early stop, then gap-midpoint escalation entered only
    when the candidates disagree."""

    name = "ladder"

    def __init__(self, min_points: int = MIN_POINTS,
                 stability_rtol: float = STABILITY_RTOL,
                 disagree_rtol: float = DISAGREE_RTOL,
                 max_extra_points: int = MAX_EXTRA_POINTS):
        self.min_points = max(2, min_points)
        self.stability_rtol = stability_rtol
        self.disagree_rtol = disagree_rtol
        self.max_extra_points = max_extra_points

    def next_size(self, state: PlacementState) -> Optional[float]:
        measured = state.measured
        remaining = [s for s in state.ladder if s not in measured]
        if remaining:
            # early stop mid-ladder once the fit is confident AND stable
            if (state.fit is not None and len(state.sizes) >= self.min_points
                    and _confident(state.fit) and state.stable):
                return None
            return remaining[0]          # ladder is ascending: smallest first
        # base ladder done: candidate disagreement gates STARTING to
        # escalate; once escalating, extra points run to confidence or the
        # cap (PR-2 semantics — the first midpoint shrinking the spread
        # under the threshold must not strand a still-unconfident fit)
        if (state.fit is None or _confident(state.fit)
                or state.beyond_base >= self.max_extra_points
                or (state.beyond_base == 0
                    and candidate_disagreement(state.fit, state.full_size)
                    <= self.disagree_rtol)):
            return None
        mids = [m for m in gap_midpoints(state.sizes, self.max_extra_points)
                if m not in measured]
        return mids[0] if mids else None


class InfoGainPlacer:
    """Information-optimal placement: profile the size whose measurement
    is expected to shrink candidate-model disagreement at full_size the
    most; stop when the best expected shrink falls below the stability
    threshold (the answer would not change) or the fit is confident and
    stable."""

    name = "infogain"

    def __init__(self, min_points: int = MIN_POINTS,
                 stability_rtol: float = STABILITY_RTOL,
                 max_extra_points: int = MAX_EXTRA_POINTS,
                 grid_points: int = 3,
                 cost_aware: bool = True):
        self.min_points = max(2, min_points)
        self.stability_rtol = stability_rtol
        self.max_extra_points = max_extra_points
        self.grid_points = grid_points
        # cost_aware: among the informative sizes, buy bits-per-second —
        # rank by expected gain per predicted wall-second instead of raw
        # gain, so a ten-minute ProfilingBudget stretches further. The
        # stop rule stays on RAW gain (a cheap uninformative point must
        # not keep the loop alive), and with constant per-point walls the
        # weighted argmax equals the raw one.
        self.cost_aware = cost_aware
        # single-model (non-zoo) fitters have no candidate set to
        # disagree: fall back to FULL ladder semantics — prefix AND
        # midpoint escalation — not just the prefix
        self._ladder_fallback = LadderPlacer(
            min_points=min_points, stability_rtol=stability_rtol,
            max_extra_points=max_extra_points)

    # -- candidate pool -----------------------------------------------------
    def _pool(self, state: PlacementState) -> List[float]:
        """Unmeasured ladder sizes plus widest-gap midpoints: the same
        sizes either strategy could reach, ranked here by information
        instead of position."""
        measured = state.measured
        pool = [s for s in state.ladder if s not in measured]
        pool += [m for m in gap_midpoints(state.sizes, self.grid_points)
                 if m not in measured and m not in pool]
        return pool

    # -- expected disagreement ----------------------------------------------
    @staticmethod
    def _refit_candidates(fits: dict, sizes: Sequence[float],
                          mems: Sequence[float]) -> dict:
        """Scores-free refit of the currently fitted candidate kinds on
        augmented data. LOOCV selection is irrelevant for a hypothesis
        refit — only the candidates' full-size predictions feed the
        spread — so paying fit_zoo's n-fold held-out scoring here would
        be an O(n x candidates) pure waste per scored pool size."""
        out = {}
        for kind, m in fits.items():
            fit = getattr(type(m), "fit", None)
            if callable(fit):
                refit = fit(sizes, mems)
            elif kind == LinearMemoryModel.kind:
                refit = fit_memory_model(sizes, mems)
            else:
                continue
            if refit is not None:
                out[kind] = refit
        return out

    def _expected_disagreement(self, state: PlacementState, fit: ZooFit,
                               size: float) -> float:
        """Average over truth hypotheses h (the currently fitted
        candidates) of the candidate spread at full_size after refitting
        everyone as if mem(size) == h.predict(size)."""
        hyps = fit.fits or {}
        if not hyps:
            return 0.0
        spreads = []
        for h in hyps.values():
            try:
                y = float(h.predict(size))
            except (OverflowError, ValueError):
                continue
            if not math.isfinite(y) or y < 0:
                continue
            refit = self._refit_candidates(hyps, state.sizes + [size],
                                           state.mems + [y])
            spreads.append(prediction_spread(refit, state.full_size))
        if not spreads:
            return math.inf
        return sum(spreads) / len(spreads)

    # -- protocol -----------------------------------------------------------
    def next_size(self, state: PlacementState) -> Optional[float]:
        measured = state.measured
        ladder = state.ladder
        # seeds: the two cheapest points (no fit exists yet, so nothing
        # can be ranked by information — and a single-model fitter, which
        # never will rank, must keep the PR-2 cheap-prefix cost profile).
        # With zoo candidates, the first gain-scored choice then jumps to
        # whichever size separates them best, usually the far end.
        if len(state.sizes) < 2:
            remaining = [s for s in ladder if s not in measured]
            return remaining[0] if remaining else None
        if (state.fit is not None and len(state.sizes) >= self.min_points
                and _confident(state.fit) and state.stable):
            return None
        if state.beyond_base >= self.max_extra_points:
            return None
        if not isinstance(state.fit, ZooFit):
            # custom single-model fitter: delegate to ladder semantics
            # (prefix + escalation), preserving PR-2 behavior exactly
            return self._ladder_fallback.next_size(state)
        pool = self._pool(state)
        if not pool:
            return None
        current = candidate_disagreement(state.fit, state.full_size)
        scored = [(current - self._expected_disagreement(state, state.fit,
                                                         s), s)
                  for s in pool]
        best_gain, best_size = max(scored)
        # the answer is as settled as it is going to get: every remaining
        # measurement is expected to move the candidate spread by less
        # than the stability threshold
        if (len(state.sizes) >= self.min_points
                and best_gain < self.stability_rtol):
            return None
        if self.cost_aware:
            return self._cheapest_informative(state, scored, best_size)
        return best_size

    # -- cost-aware ranking -------------------------------------------------
    def _predicted_wall(self, state: PlacementState,
                        size: float) -> Optional[float]:
        """OLS wall-time estimate for profiling `size`, from the walls of
        the points measured so far; None when walls are unavailable."""
        walls = state.walls
        if len(walls) != len(state.sizes) or len(walls) < 2:
            return None
        m = fit_memory_model(state.sizes, walls)
        w = m.predict(size)
        if not math.isfinite(w) or w <= 0.0:
            w = sum(walls) / len(walls)
        return max(w, 1e-9)

    def _cheapest_informative(self, state: PlacementState, scored,
                              best_size: float) -> float:
        """Among sizes whose expected gain clears the stability threshold
        (each one individually worth measuring), pick the best expected
        gain per predicted wall-second. Falls back to the raw argmax when
        no size clears the bar alone (min_points not yet reached) or no
        wall model exists."""
        informative = [(g, s) for g, s in scored
                       if g >= self.stability_rtol]
        if not informative:
            return best_size
        weighted = []
        for g, s in informative:
            w = self._predicted_wall(state, s)
            if w is None:
                return best_size
            weighted.append((g / w, s))
        return max(weighted)[1]


@dataclass
class PlacementOutcome:
    """What one placement-driven acquisition produced."""
    sizes: List[float]
    mems: List[float]
    results: List[object]            # ProfileResults, aligned with sizes
    fit: object
    fresh: int                       # profile runs actually executed
    cache_hits: int                  # points served from caches/stores
    early_stop: bool                 # confident+stable before the base end
    escalated: bool                  # measured a size outside the base ladder
    budget_exhausted: bool           # a point was denied by the budget
    requirement_trace: List[float]


def drive_placement(placer: PointPlacer, ladder: Sequence[float],
                    full_size: float, acquire, fit_fn) -> PlacementOutcome:
    """The one adaptive-acquisition loop every caller drives: ask the
    placer for the next size, acquire it (None == budget denial), refit,
    update stability, repeat until the placer stops or the budget does.

    `acquire(size) -> Optional[(ProfileResult, fresh)]` owns caching and
    budget accounting (see repro.pipeline.acquisition.PointSource);
    `fit_fn(sizes, mems)` is the model-fitting stage."""
    base = sorted(float(s) for s in ladder)
    state = PlacementState(ladder=base, full_size=float(full_size))
    results: List[object] = []
    trace: List[float] = []
    rtol = getattr(placer, "stability_rtol", STABILITY_RTOL)
    fresh = hits = 0
    prev_pred: Optional[float] = None
    exhausted = False
    while True:
        nxt = placer.next_size(state)
        if nxt is None:
            break
        got = acquire(nxt)
        if got is None:
            exhausted = True
            break
        r, was_fresh = got
        fresh += int(was_fresh)
        hits += int(not was_fresh)
        state.sizes.append(float(nxt))
        state.mems.append(r.job_mem_bytes)
        state.walls.append(float(getattr(r, "wall_s", 0.0)))
        results.append(r)
        if len(state.sizes) >= 2:
            fit = fit_fn(state.sizes, state.mems)
            pred = float(fit.predict(full_size))
            trace.append(pred)
            state.stable = (prev_pred is not None
                            and math.isfinite(pred) and pred != 0.0
                            and abs(pred - prev_pred) <= rtol * abs(pred))
            prev_pred = pred
            state.fit = fit
    if state.fit is None:            # budget denied even a second point
        state.fit = fit_fn(state.sizes, state.mems)
    base_set = set(base)
    early = (not exhausted and len(state.sizes) < len(base)
             and _confident(state.fit) and state.stable)
    escalated = any(s not in base_set for s in state.sizes)
    return PlacementOutcome(state.sizes, state.mems, results, state.fit,
                            fresh, hits, early, escalated, exhausted, trace)


def make_placer(placement) -> PointPlacer:
    """Resolve a placement spec: a PointPlacer instance passes through,
    a name ("infogain" | "ladder") builds the default instance."""
    if placement is None:
        return InfoGainPlacer()
    if isinstance(placement, str):
        if placement == "infogain":
            return InfoGainPlacer()
        if placement == "ladder":
            return LadderPlacer()
        raise ValueError(f"unknown placement {placement!r}; "
                         f"expected one of {PLACEMENTS}")
    if not hasattr(placement, "next_size"):
        raise TypeError("placement must be a name or a PointPlacer "
                        "(object with next_size(state))")
    return placement
