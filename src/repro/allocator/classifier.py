"""Flora-style nearest-job classification (arXiv:2502.21046).

When no zoo candidate passes its confidence gate, Crispy degenerates to the
BFA baseline and the profiling work is discarded. Flora's observation: jobs
with similar resource-usage *shape* want similar allocations, so an
unusable profile can still be matched against previously seen jobs and the
neighbor's allocation transferred.

The classifier embeds a profiling ladder into a small scale-invariant
feature vector — the memory curve resampled onto a fixed grid and
normalized by its peak, plus growth, roughness, and linear-fit-R² summary
terms — and answers nearest-neighbor queries under a Euclidean distance
gate. Every job the AllocationService profiles is `observe`d here (even
gate-failing ones), so the feature store grows with traffic and nothing is
thrown away.

Memory shape alone cannot separate jobs whose memory curves agree but
whose *runtime* curves do not (a linear-memory scan vs a linear-memory
quadratic join): profiling already measures per-point wall time, so the
ladder's runtime-vs-size curve is embedded the same scale-invariant way
(`runtime_features`) and concatenated into the distance whenever both
sides observed it. Jobs observed without runtimes (e.g. warm-started from
persisted registry ladders, which keep only sizes/mems) fall back to the
memory-shape distance, so the feature store never fragments.

Flora additionally classifies on *categorical* job descriptors — input
format, operator palette — because two jobs can tie on every measured
curve yet be different programs. `observe`/`classify` accept an optional
set of string tags (e.g. ``{"format:parquet", "op:join"}``); when both
sides carry tags, their Jaccard distance joins the numeric blocks as
`TAG_WEIGHT` virtual feature components in the same RMS pooling, so the
distance gate's scale is unchanged and tags act as a tie-breaker rather
than a veto. Sides without tags participate exactly as before.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, FrozenSet, Iterable, List, Optional, Sequence

import numpy as np

from repro.core.memory_model import fit_memory_model

FEATURE_POINTS = 8          # resampled curve resolution
RUNTIME_POINTS = 8          # resampled runtime-curve resolution
# virtual components the categorical block adds to the RMS pooling. The
# tie-breaker contract bounds it: even a fully disjoint palette (Jaccard
# distance 1) over byte-identical curves must stay under the distance
# gate, i.e. sqrt(W / (n_numeric + W)) < DEFAULT_MAX_DISTANCE for the
# smallest numeric block (memory-only, n = FEATURE_POINTS + 3 = 11),
# which needs W < ~0.73. W = 0.5 keeps tags decisive on exact ties and
# influential on near-ties without ever vetoing a curve match alone.
TAG_WEIGHT = 0.5
DEFAULT_MAX_DISTANCE = 0.25


def _resample_unit_curve(sizes: Sequence[float], values: Sequence[float],
                         points: int) -> Optional[np.ndarray]:
    """Values resampled onto a unit grid over the size span, normalized by
    their peak magnitude — the shared scale-invariant embedding."""
    x = np.asarray(sizes, dtype=np.float64)
    y = np.asarray(values, dtype=np.float64)
    keep = np.isfinite(x) & np.isfinite(y)
    x, y = x[keep], y[keep]
    if x.size < 2:
        return None
    order = np.argsort(x)
    x, y = x[order], y[order]
    span = x[-1] - x[0]
    t = (x - x[0]) / span if span > 0 else np.zeros_like(x)
    scale = float(np.abs(y).max()) or 1.0
    grid = np.linspace(0.0, 1.0, points)
    return np.interp(grid, t, y / scale)


def profile_features(sizes: Sequence[float],
                     mems: Sequence[float]) -> np.ndarray:
    """Scale-invariant embedding of a profiling ladder's memory curve."""
    curve = _resample_unit_curve(sizes, mems, FEATURE_POINTS)
    if curve is None:
        return np.zeros(FEATURE_POINTS + 3)
    growth = float(curve[-1] - curve[0])
    rough = float(np.sqrt(np.mean(np.diff(curve, 2) ** 2))) \
        if curve.size >= 3 else 0.0
    x = np.asarray(sizes, dtype=np.float64)
    y = np.asarray(mems, dtype=np.float64)
    lin = fit_memory_model(x, y)
    r2c = float(np.clip(lin.r2, 0.0, 1.0))
    return np.concatenate([curve, [growth, rough, r2c]])


def runtime_features(sizes: Sequence[float],
                     runtimes: Optional[Sequence[float]]
                     ) -> Optional[np.ndarray]:
    """Scale-invariant embedding of the ladder's runtime-vs-size curve, or
    None when fewer than two finite runtimes were measured. The convexity
    term separates linear from superlinear runtime growth even when the
    resampled curves are close."""
    if runtimes is None or len(runtimes) != len(sizes):
        return None
    curve = _resample_unit_curve(sizes, runtimes, RUNTIME_POINTS)
    if curve is None:
        return None
    growth = float(curve[-1] - curve[0])
    convexity = float(np.mean(np.diff(curve, 2))) if curve.size >= 3 else 0.0
    return np.concatenate([curve, [growth, convexity]])


def feature_distance(a: np.ndarray, b: np.ndarray) -> float:
    return float(np.sqrt(np.mean((a - b) ** 2)))


def tag_distance(a: FrozenSet[str], b: FrozenSet[str]) -> float:
    """Jaccard distance between two categorical tag sets (0 == identical
    palettes, 1 == disjoint)."""
    if not a and not b:
        return 0.0
    union = len(a | b)
    return 1.0 - len(a & b) / union if union else 0.0


@dataclass
class Classification:
    neighbor: str               # signature of the nearest observed job
    distance: float


class NearestJobClassifier:
    def __init__(self, max_distance: float = DEFAULT_MAX_DISTANCE):
        self.max_distance = max_distance
        self._features: Dict[str, np.ndarray] = {}
        self._runtime: Dict[str, Optional[np.ndarray]] = {}
        self._tags: Dict[str, Optional[FrozenSet[str]]] = {}

    def __len__(self) -> int:
        return len(self._features)

    def jobs(self) -> List[str]:
        return sorted(self._features)

    def has(self, signature: str) -> bool:
        return signature in self._features

    def observe(self, signature: str, sizes: Sequence[float],
                mems: Sequence[float],
                runtimes: Optional[Sequence[float]] = None,
                tags: Optional[Iterable[str]] = None) -> None:
        if len(sizes) >= 2:
            self._features[signature] = profile_features(sizes, mems)
            self._runtime[signature] = runtime_features(sizes, runtimes)
            if tags is not None:
                self._tags[signature] = frozenset(tags)
            else:
                # a tagless re-observation (service plan-cache miss, registry
                # warm-up) must not erase a previously observed palette
                self._tags.setdefault(signature, None)

    def _distance(self, query_mem: np.ndarray,
                  query_rt: Optional[np.ndarray],
                  query_tags: Optional[FrozenSet[str]], sig: str) -> float:
        """Memory-shape distance, extended over the runtime block and the
        categorical tag block when both sides observed them. Pooling is
        RMS over all (virtual) components, so the gate's scale is
        unchanged however many blocks participate."""
        blocks = [(query_mem, self._features[sig])]
        cand_rt = self._runtime.get(sig)
        if query_rt is not None and cand_rt is not None:
            blocks.append((query_rt, cand_rt))
        sq_sum = sum(float(((a - b) ** 2).sum()) for a, b in blocks)
        n = sum(a.size for a, _b in blocks)
        cand_tags = self._tags.get(sig)
        if query_tags is not None and cand_tags is not None:
            sq_sum += TAG_WEIGHT * tag_distance(query_tags, cand_tags) ** 2
            n += TAG_WEIGHT
        return float(np.sqrt(sq_sum / n))

    def classify(self, sizes: Sequence[float], mems: Sequence[float],
                 runtimes: Optional[Sequence[float]] = None,
                 exclude: Iterable[str] = (),
                 tags: Optional[Iterable[str]] = None
                 ) -> Optional[Classification]:
        """Nearest observed job under the distance gate, or None."""
        query_mem = profile_features(sizes, mems)
        query_rt = runtime_features(sizes, runtimes)
        query_tags = frozenset(tags) if tags is not None else None
        skip = set(exclude)
        best: Optional[Classification] = None
        for sig in self._features:
            if sig in skip:
                continue
            d = self._distance(query_mem, query_rt, query_tags, sig)
            if best is None or d < best.distance:
                best = Classification(sig, d)
        if best is None or best.distance > self.max_distance:
            return None
        return best
