"""Flora-style nearest-job classification (arXiv:2502.21046).

When no zoo candidate passes its confidence gate, Crispy degenerates to the
BFA baseline and the profiling work is discarded. Flora's observation: jobs
with similar resource-usage *shape* want similar allocations, so an
unusable profile can still be matched against previously seen jobs and the
neighbor's allocation transferred.

The classifier embeds a profiling ladder into a small scale-invariant
feature vector — the memory curve resampled onto a fixed grid and
normalized by its peak, plus growth, roughness, and linear-fit-R² summary
terms — and answers nearest-neighbor queries under a Euclidean distance
gate. Every job the AllocationService profiles is `observe`d here (even
gate-failing ones), so the feature store grows with traffic and nothing is
thrown away.

Memory shape alone cannot separate jobs whose memory curves agree but
whose *runtime* curves do not (a linear-memory scan vs a linear-memory
quadratic join): profiling already measures per-point wall time, so the
ladder's runtime-vs-size curve is embedded the same scale-invariant way
(`runtime_features`) and concatenated into the distance whenever both
sides observed it. Jobs observed without runtimes (e.g. warm-started from
persisted registry ladders, which keep only sizes/mems) fall back to the
memory-shape distance, so the feature store never fragments.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterable, List, Optional, Sequence

import numpy as np

from repro.core.memory_model import fit_memory_model

FEATURE_POINTS = 8          # resampled curve resolution
RUNTIME_POINTS = 8          # resampled runtime-curve resolution
DEFAULT_MAX_DISTANCE = 0.25


def _resample_unit_curve(sizes: Sequence[float], values: Sequence[float],
                         points: int) -> Optional[np.ndarray]:
    """Values resampled onto a unit grid over the size span, normalized by
    their peak magnitude — the shared scale-invariant embedding."""
    x = np.asarray(sizes, dtype=np.float64)
    y = np.asarray(values, dtype=np.float64)
    keep = np.isfinite(x) & np.isfinite(y)
    x, y = x[keep], y[keep]
    if x.size < 2:
        return None
    order = np.argsort(x)
    x, y = x[order], y[order]
    span = x[-1] - x[0]
    t = (x - x[0]) / span if span > 0 else np.zeros_like(x)
    scale = float(np.abs(y).max()) or 1.0
    grid = np.linspace(0.0, 1.0, points)
    return np.interp(grid, t, y / scale)


def profile_features(sizes: Sequence[float],
                     mems: Sequence[float]) -> np.ndarray:
    """Scale-invariant embedding of a profiling ladder's memory curve."""
    curve = _resample_unit_curve(sizes, mems, FEATURE_POINTS)
    if curve is None:
        return np.zeros(FEATURE_POINTS + 3)
    growth = float(curve[-1] - curve[0])
    rough = float(np.sqrt(np.mean(np.diff(curve, 2) ** 2))) \
        if curve.size >= 3 else 0.0
    x = np.asarray(sizes, dtype=np.float64)
    y = np.asarray(mems, dtype=np.float64)
    lin = fit_memory_model(x, y)
    r2c = float(np.clip(lin.r2, 0.0, 1.0))
    return np.concatenate([curve, [growth, rough, r2c]])


def runtime_features(sizes: Sequence[float],
                     runtimes: Optional[Sequence[float]]
                     ) -> Optional[np.ndarray]:
    """Scale-invariant embedding of the ladder's runtime-vs-size curve, or
    None when fewer than two finite runtimes were measured. The convexity
    term separates linear from superlinear runtime growth even when the
    resampled curves are close."""
    if runtimes is None or len(runtimes) != len(sizes):
        return None
    curve = _resample_unit_curve(sizes, runtimes, RUNTIME_POINTS)
    if curve is None:
        return None
    growth = float(curve[-1] - curve[0])
    convexity = float(np.mean(np.diff(curve, 2))) if curve.size >= 3 else 0.0
    return np.concatenate([curve, [growth, convexity]])


def feature_distance(a: np.ndarray, b: np.ndarray) -> float:
    return float(np.sqrt(np.mean((a - b) ** 2)))


@dataclass
class Classification:
    neighbor: str               # signature of the nearest observed job
    distance: float


class NearestJobClassifier:
    def __init__(self, max_distance: float = DEFAULT_MAX_DISTANCE):
        self.max_distance = max_distance
        self._features: Dict[str, np.ndarray] = {}
        self._runtime: Dict[str, Optional[np.ndarray]] = {}

    def __len__(self) -> int:
        return len(self._features)

    def jobs(self) -> List[str]:
        return sorted(self._features)

    def has(self, signature: str) -> bool:
        return signature in self._features

    def observe(self, signature: str, sizes: Sequence[float],
                mems: Sequence[float],
                runtimes: Optional[Sequence[float]] = None) -> None:
        if len(sizes) >= 2:
            self._features[signature] = profile_features(sizes, mems)
            self._runtime[signature] = runtime_features(sizes, runtimes)

    def _distance(self, query_mem: np.ndarray,
                  query_rt: Optional[np.ndarray], sig: str) -> float:
        """Memory-shape distance, extended over the runtime block when
        both sides observed one (RMS over the concatenated vector, so the
        gate's scale is unchanged)."""
        cand_rt = self._runtime.get(sig)
        if query_rt is not None and cand_rt is not None:
            return feature_distance(
                np.concatenate([query_mem, query_rt]),
                np.concatenate([self._features[sig], cand_rt]))
        return feature_distance(query_mem, self._features[sig])

    def classify(self, sizes: Sequence[float], mems: Sequence[float],
                 runtimes: Optional[Sequence[float]] = None,
                 exclude: Iterable[str] = ()) -> Optional[Classification]:
        """Nearest observed job under the distance gate, or None."""
        query_mem = profile_features(sizes, mems)
        query_rt = runtime_features(sizes, runtimes)
        skip = set(exclude)
        best: Optional[Classification] = None
        for sig in self._features:
            if sig in skip:
                continue
            d = self._distance(query_mem, query_rt, sig)
            if best is None or d < best.distance:
                best = Classification(sig, d)
        if best is None or best.distance > self.max_distance:
            return None
        return best
