"""Flora-style nearest-job classification (arXiv:2502.21046).

When no zoo candidate passes its confidence gate, Crispy degenerates to the
BFA baseline and the profiling work is discarded. Flora's observation: jobs
with similar resource-usage *shape* want similar allocations, so an
unusable profile can still be matched against previously seen jobs and the
neighbor's allocation transferred.

The classifier embeds a profiling ladder into a small scale-invariant
feature vector — the memory curve resampled onto a fixed grid and
normalized by its peak, plus growth, roughness, and linear-fit-R² summary
terms — and answers nearest-neighbor queries under a Euclidean distance
gate. Every job the AllocationService profiles is `observe`d here (even
gate-failing ones), so the feature store grows with traffic and nothing is
thrown away.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterable, List, Optional, Sequence

import numpy as np

from repro.core.memory_model import fit_memory_model

FEATURE_POINTS = 8          # resampled curve resolution
DEFAULT_MAX_DISTANCE = 0.25


def profile_features(sizes: Sequence[float],
                     mems: Sequence[float]) -> np.ndarray:
    """Scale-invariant embedding of a profiling ladder."""
    x = np.asarray(sizes, dtype=np.float64)
    y = np.asarray(mems, dtype=np.float64)
    order = np.argsort(x)
    x, y = x[order], y[order]
    if x.size == 0:
        return np.zeros(FEATURE_POINTS + 3)
    span = x[-1] - x[0]
    t = (x - x[0]) / span if span > 0 else np.zeros_like(x)
    scale = float(np.abs(y).max()) or 1.0
    yn = y / scale
    grid = np.linspace(0.0, 1.0, FEATURE_POINTS)
    curve = np.interp(grid, t, yn)
    growth = float(curve[-1] - curve[0])
    rough = float(np.sqrt(np.mean(np.diff(curve, 2) ** 2))) \
        if curve.size >= 3 else 0.0
    lin = fit_memory_model(x, y)
    r2c = float(np.clip(lin.r2, 0.0, 1.0))
    return np.concatenate([curve, [growth, rough, r2c]])


def feature_distance(a: np.ndarray, b: np.ndarray) -> float:
    return float(np.sqrt(np.mean((a - b) ** 2)))


@dataclass
class Classification:
    neighbor: str               # signature of the nearest observed job
    distance: float


class NearestJobClassifier:
    def __init__(self, max_distance: float = DEFAULT_MAX_DISTANCE):
        self.max_distance = max_distance
        self._features: Dict[str, np.ndarray] = {}

    def __len__(self) -> int:
        return len(self._features)

    def jobs(self) -> List[str]:
        return sorted(self._features)

    def has(self, signature: str) -> bool:
        return signature in self._features

    def observe(self, signature: str, sizes: Sequence[float],
                mems: Sequence[float]) -> None:
        if len(sizes) >= 2:
            self._features[signature] = profile_features(sizes, mems)

    def classify(self, sizes: Sequence[float], mems: Sequence[float],
                 exclude: Iterable[str] = ()) -> Optional[Classification]:
        """Nearest observed job under the distance gate, or None."""
        query = profile_features(sizes, mems)
        skip = set(exclude)
        best: Optional[Classification] = None
        for sig, feat in self._features.items():
            if sig in skip:
                continue
            d = feature_distance(query, feat)
            if best is None or d < best.distance:
                best = Classification(sig, d)
        if best is None or best.distance > self.max_distance:
            return None
        return best
