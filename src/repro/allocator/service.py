"""AllocationService: allocation as a servable, stateful subsystem.

Request lifecycle (one worker thread, many submitters):

  submit() --+                          +--> registry hit: skip profiling
             |   drain window (coalesce |
  submit() --+-> concurrent requests    +--> LRU-cached ladder profile
             |   into one batch, group  |      -> model-zoo fit (LOOCV)
  submit() --+   by job signature)      |      -> confident: persist model
                                        |      -> else: nearest-job
                                        |         classifier transfer
                                        +--> per-request config selection

Requests for the same job signature that land in one batch share a single
profiling ladder (dedup); repeats across batches hit the model registry and
never profile again; distinct requests that need the same (signature, size)
sample hit the ProfileResult LRU. Per-profile work is therefore done at
most once per (signature, size) while the cache holds.

Fallback chain when no zoo candidate is confident — Flora-style (see
classifier.py): transfer the nearest observed neighbor's registered model,
else the neighbor's best historical config, else the paper's BFA baseline
(requirement 0). Profiled ladders are always `observe`d by the classifier,
so even gate-failing jobs contribute to future classifications.

Profiling orchestration (repro.profiling) is delegated, not inlined:

  adaptive=True      ladders run through the AdaptiveLadderScheduler —
                     smallest point first, refit after each, stop early
                     once the selected model is confident and its
                     requirement prediction has stabilized; escalate past
                     the base ladder only when candidates disagree.
  budget=            a shared ProfilingBudget gates every fresh profile
                     run (adaptive or fixed) — the paper's ten-minute
                     envelope enforced service-wide.
  store=             a file-locked ProfileStore backs the in-process LRU:
                     points and calibrated anchors profiled by *any*
                     process are reused, and `_ladder_of` skips anchor
                     guessing for signatures with a persisted anchor.
  executor=          a ProfilingExecutor profiles fixed ladders
                     point-concurrently and fans independent signature
                     groups of one batch out over its pool.

Shared state (repro.state) is unified behind one knob:

  backend=           a `repro.state.StateBackend` (InMemoryBackend,
                     FileBackend directory, or DaemonBackend socket).
                     When given, the service builds its ProfileStore and
                     model registry over it unless explicit `store=` /
                     `registry=` override them — so N service processes
                     pointed at one FileBackend root or one crispy-daemon
                     share profile points, anchors and confident models.
                     Pair it with `ProfilingBudget(..., backend=backend)`
                     and those N processes also arbitrate ONE profiling
                     envelope through atomic backend reservations instead
                     of each spending a full copy.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import OrderedDict
from concurrent.futures import Future, InvalidStateError
from dataclasses import dataclass
from typing import Callable, List, Optional, Sequence, Tuple

from repro.allocator.classifier import NearestJobClassifier
from repro.allocator.model_zoo import fit_zoo
from repro.allocator.registry import ModelRegistry
from repro.core.catalog import ClusterConfig
from repro.core.history import ExecutionHistory
from repro.core.profiler import ProfileResult
from repro.core.sampling import ladder_from_anchor
from repro.core.selector import (DEFAULT_OVERHEAD_GIB, Selection,
                                 select_crispy, select_like)

GiB = 1024 ** 3


def _resolve(fut: Future, result=None, exc: Optional[Exception] = None):
    """Resolve a future the caller may have cancelled (or be cancelling
    concurrently) without letting InvalidStateError kill the worker."""
    if fut.cancelled():
        return
    try:
        if exc is not None:
            fut.set_exception(exc)
        else:
            fut.set_result(result)
    except InvalidStateError:       # cancelled between the check and the set
        pass


@dataclass
class AllocationRequest:
    job: str
    profile_at: Callable[[float], ProfileResult]
    full_size: float
    anchor: Optional[float] = None
    sizes: Optional[List[float]] = None
    signature: Optional[str] = None     # defaults to the job name
    leeway: Optional[float] = None      # overrides the service default
    adaptive: Optional[bool] = None     # overrides the service default

    @property
    def sig(self) -> str:
        return self.signature if self.signature is not None else self.job


@dataclass
class AllocationResponse:
    job: str
    signature: str
    source: str                  # registry | zoo | classifier | baseline
    candidate: Optional[str]     # winning model kind (None on baseline)
    model: Optional[object]
    requirement_gib: float
    selection: Selection
    neighbor: Optional[str] = None
    profiled: int = 0            # fresh profile_at calls for this plan
    cache_hits: int = 0          # ladder points served from the LRU/store
    wall_s: float = 0.0
    early_stop: bool = False     # adaptive schedule stopped before 5 points
    escalated: bool = False      # adaptive schedule spent extra points
    budget_exhausted: bool = False   # the budget denied at least one point


@dataclass
class ServiceStats:
    requests: int = 0
    batches: int = 0
    profile_calls: int = 0
    cache_hits: int = 0
    registry_hits: int = 0
    zoo_fits: int = 0
    zoo_confident: int = 0
    classifier_fallbacks: int = 0
    baseline_fallbacks: int = 0
    plan_cache_hits: int = 0     # unconfident repeats answered w/o refit
    flush_errors: int = 0        # registry persistence failures survived
    store_hits: int = 0          # ladder points served by the shared store
    adaptive_plans: int = 0      # plans scheduled adaptively
    early_stops: int = 0         # adaptive plans that stopped early
    escalations: int = 0         # adaptive plans that spent extra points
    points_saved: int = 0        # ladder points adaptive plans did not run
    budget_denied: int = 0       # plans the budget cut short

    @property
    def profile_hit_rate(self) -> float:
        total = self.profile_calls + self.cache_hits
        return self.cache_hits / total if total else 0.0


@dataclass
class _Plan:
    """Per-signature outcome shared by every request in a batch group."""
    source: str
    model: Optional[object]
    candidate: Optional[str]
    neighbor: Optional[str] = None
    neighbor_selection: Optional[Selection] = None
    profiled: int = 0
    cache_hits: int = 0
    early_stop: bool = False
    escalated: bool = False
    budget_exhausted: bool = False


class AllocationService:
    def __init__(self, catalog: List[ClusterConfig],
                 history: ExecutionHistory,
                 registry: Optional[ModelRegistry] = None,
                 classifier: Optional[NearestJobClassifier] = None,
                 candidates: Optional[Sequence] = None,
                 overhead_per_node_gib: float = DEFAULT_OVERHEAD_GIB,
                 leeway: float = 0.0,
                 profile_cache_size: int = 512,
                 batch_window_s: float = 0.005,
                 adaptive: bool = False,
                 budget=None,               # repro.profiling ProfilingBudget
                 store=None,                # repro.profiling ProfileStore
                 executor=None,             # repro.profiling ProfilingExecutor
                 scheduler=None,            # AdaptiveLadderScheduler override
                 backend=None):             # repro.state StateBackend
        self.catalog = catalog
        self.history = history
        self.backend = backend
        if backend is not None:
            # deferred import: repro.profiling imports allocator submodules
            from repro.profiling.store import (BackendModelRegistry,
                                               ProfileStore)
            if store is None:
                store = ProfileStore(backend=backend, namespace="profiles")
            if registry is None:
                registry = BackendModelRegistry(backend,
                                                namespace="registry")
        self.registry = registry if registry is not None else ModelRegistry()
        self.classifier = classifier if classifier is not None \
            else NearestJobClassifier()
        self.candidates = candidates
        self.overhead = overhead_per_node_gib
        self.leeway = leeway
        self.batch_window_s = batch_window_s
        self.adaptive = adaptive
        self.budget = budget
        self.store = store
        self.executor = executor
        self._scheduler = scheduler
        self.stats = ServiceStats()

        self._cache: "OrderedDict[Tuple[str, float], ProfileResult]" = \
            OrderedDict()
        self._cache_cap = profile_cache_size
        # negative-outcome cache: (sig, ladder) -> unconfident _Plan, so a
        # noisy job resubmitted N times doesn't redo the zoo LOOCV fit and
        # classifier scan N times. Cleared whenever the observable world
        # changes (new signature observed / new model registered), because
        # either can turn a baseline outcome into a classifier one.
        # Guarded by _plan_lock: with an executor, a batch's signature
        # groups plan concurrently.
        self._plan_cache: "OrderedDict[Tuple[str, Tuple[float, ...]], _Plan]" \
            = OrderedDict()
        self._plan_cache_hist_version = history.version
        self._plan_lock = threading.Lock()
        self._lock = threading.Lock()
        self._cv = threading.Condition(self._lock)
        self._pending: List[Tuple[AllocationRequest, Future]] = []
        self._worker: Optional[threading.Thread] = None
        self._closed = False

        # warm the classifier from persisted registry records: a restarted
        # service classifies against every CONFIDENT signature it ever
        # registered (gate-failing ladders live only in memory and are
        # re-observed as their jobs resubmit)
        for rec in self.registry.records():
            self.classifier.observe(rec.signature, rec.sizes, rec.mems)

    def _shared_backend(self):
        for b in (self.backend, getattr(self.store, "backend", None),
                  getattr(self.registry, "backend", None),
                  getattr(self.budget, "backend", None)):
            if b is not None:
                return b
        return None

    @property
    def backend_kind(self) -> Optional[str]:
        """Kind of the shared-state backend this service operates over
        ("memory" | "file" | "daemon"), from whichever shared component
        carries one; None for a fully process-local service."""
        return getattr(self._shared_backend(), "kind", None)

    @property
    def backend_transport(self) -> Optional[str]:
        """Transport of a daemon backend ("unix" | "tcp"); None for
        local backends — the monitoring signal that distinguishes a
        co-located daemon from a multi-host one."""
        return getattr(self._shared_backend(), "transport", None)

    @property
    def backend_address(self) -> Optional[str]:
        """Address a daemon backend connects to (unix path or host:port);
        None for local backends."""
        return getattr(self._shared_backend(), "address", None)

    # -- public -------------------------------------------------------------
    def submit(self, req: AllocationRequest) -> "Future[AllocationResponse]":
        fut: Future = Future()
        with self._cv:
            if self._closed:
                raise RuntimeError("AllocationService is closed")
            self._pending.append((req, fut))
            self._ensure_worker_locked()
            self._cv.notify()
        return fut

    def allocate(self, req: AllocationRequest,
                 timeout: Optional[float] = None) -> AllocationResponse:
        return self.submit(req).result(timeout)

    def allocate_many(self, reqs: Sequence[AllocationRequest],
                      timeout: Optional[float] = None
                      ) -> List[AllocationResponse]:
        futs = [self.submit(r) for r in reqs]
        return [f.result(timeout) for f in futs]

    def close(self) -> None:
        with self._cv:
            self._closed = True
            self._cv.notify_all()
        if self._worker is not None:
            self._worker.join(timeout=5.0)
        try:
            self.registry.flush()   # durability backstop for deferred puts
        except Exception:
            self.stats.flush_errors += 1

    def __enter__(self) -> "AllocationService":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- worker -------------------------------------------------------------
    def _ensure_worker_locked(self) -> None:
        if self._worker is None or not self._worker.is_alive():
            self._worker = threading.Thread(target=self._run, daemon=True)
            self._worker.start()

    def _run(self) -> None:
        while True:
            with self._cv:
                while not self._pending and not self._closed:
                    self._cv.wait()
                if not self._pending and self._closed:
                    return
            # coalesce: give concurrent submitters a window to land in the
            # same batch so same-signature ladders dedup to one profile run
            if self.batch_window_s > 0:
                time.sleep(self.batch_window_s)
            with self._cv:
                batch, self._pending = self._pending, []
            if batch:
                self._process_batch(batch)

    def _process_batch(self,
                       batch: List[Tuple[AllocationRequest, Future]]) -> None:
        with self._lock:
            self.stats.batches += 1
            self.stats.requests += len(batch)
        # pull sibling processes' work in once per batch: profile points /
        # anchors from the shared store, models from a locked registry
        if self.store is not None:
            try:
                self.store.refresh()
            except Exception:
                pass                        # stale view is still correct
        refresh = getattr(self.registry, "refresh", None)
        if refresh is not None:
            try:
                refresh()
            except Exception:
                pass
        # group by (signature, ladder): same-signature requests share one
        # profiling ladder only when they actually ask for the same ladder,
        # so coalescing never silently overrides an explicit sizes/anchor
        groups: "OrderedDict[Tuple[str, Tuple[float, ...]], " \
                "List[Tuple[AllocationRequest, Future]]]" = OrderedDict()
        for req, fut in batch:
            groups.setdefault((req.sig, self._ladder_of(req)),
                              []).append((req, fut))

        def handle_group(entry) -> None:
            (sig, _ladder), items = entry
            live = [(req, fut) for req, fut in items if not fut.cancelled()]
            if not live:                    # whole group cancelled: don't
                return                      # profile for nobody
            t0 = time.monotonic()
            try:
                plan = self._plan(sig, live[0][0])
            except Exception as e:          # a failing profile_at fails its
                for _, fut in live:         # group, never the whole batch
                    _resolve(fut, exc=e)
                return
            wall = time.monotonic() - t0
            for req, fut in live:
                try:
                    resp = self._respond(plan, req, wall)
                except Exception as e:
                    _resolve(fut, exc=e)
                    continue
                _resolve(fut, result=resp)

        entries = list(groups.items())
        if self.executor is not None and len(entries) > 1:
            # independent signatures plan (and profile) concurrently;
            # handle_group resolves its own futures and never raises
            self.executor.map_tasks(handle_group, entries)
        else:
            for entry in entries:
                handle_group(entry)
        # one file rewrite for however many models this batch registered;
        # a persistence failure (disk full, read-only) must not kill the
        # worker — models stay in memory and the next flush retries
        try:
            self.registry.flush()
        except Exception:
            with self._lock:
                self.stats.flush_errors += 1

    # -- planning -----------------------------------------------------------
    def _ladder_of(self, req: AllocationRequest) -> Tuple[float, ...]:
        if req.sizes is not None:
            return tuple(float(s) for s in req.sizes)
        anchor = req.anchor
        if anchor is None and self.store is not None:
            # a signature any process ever calibrated skips anchor guessing
            anchor = self.store.get_anchor(req.sig)
        if anchor is None:
            anchor = req.full_size * 0.01
        elif req.anchor is not None and self.store is not None \
                and self.store.get_anchor(req.sig) is None:
            try:
                self.store.put_anchor(req.sig, float(req.anchor))
            except Exception:
                pass            # a failed anchor write must never kill the
                                # worker (the batch's futures would hang)
        return tuple(float(s) for s in ladder_from_anchor(anchor).sizes)

    def _make_scheduler(self):
        if self._scheduler is None:
            # deferred import: repro.profiling imports allocator submodules
            from repro.profiling.scheduler import AdaptiveLadderScheduler
            self._scheduler = AdaptiveLadderScheduler(
                candidates=self.candidates, budget=self.budget)
        return self._scheduler

    def _plan(self, sig: str, req: AllocationRequest) -> _Plan:
        rec = self.registry.get(sig)
        if rec is not None and getattr(rec.model, "confident", False):
            with self._lock:
                self.stats.registry_hits += 1
            return _Plan("registry", rec.model, rec.candidate)

        ladder = self._ladder_of(req)
        plan_key = (sig, ladder)
        with self._plan_lock:
            # classifier/baseline plans freeze history-derived selections,
            # so a history mutation invalidates the whole negative cache
            hv = self.history.version
            if hv != self._plan_cache_hist_version:
                self._plan_cache.clear()
                self._plan_cache_hist_version = hv
            cached_plan = self._plan_cache.get(plan_key)
            if cached_plan is not None:
                self._plan_cache.move_to_end(plan_key)
                with self._lock:
                    self.stats.plan_cache_hits += 1
                # this request did no profiling; don't report the
                # original's counters or adaptive-schedule flags
                return dataclasses.replace(cached_plan, profiled=0,
                                           cache_hits=0, early_stop=False,
                                           escalated=False,
                                           budget_exhausted=False)

        sizes, mems, zoo, flags = self._measure_and_fit(sig, req,
                                                        list(ladder))
        fresh, hits, walls = flags["fresh"], flags["hits"], flags["walls"]
        with self._lock:
            self.stats.zoo_fits += 1
        with self._plan_lock:
            # never discard profiling work: even gate-failing ladders feed
            # future nearest-job classifications (memory AND runtime shape)
            newly_observed = not self.classifier.has(sig)
            self.classifier.observe(sig, sizes, mems, walls)
            if newly_observed:
                self._plan_cache.clear()  # a new neighbor may rescue others

        if zoo.confident:
            model = getattr(zoo, "model", zoo)
            candidate = getattr(zoo, "candidate",
                                getattr(zoo, "kind", "linear"))
            self.registry.put(sig, model, candidate, sizes, mems,
                              defer_save=True)
            with self._plan_lock:
                self._plan_cache.clear()  # its model may rescue others too
            with self._lock:
                self.stats.zoo_confident += 1
            return _Plan("zoo", zoo, candidate, profiled=fresh,
                         cache_hits=hits, **flags["adaptive"])

        plan = None
        with self._plan_lock:
            cls = self.classifier.classify(sizes, mems, walls,
                                           exclude=(sig,)) \
                if len(sizes) >= 2 else None
        if cls is not None:
            neighbor_rec = self.registry.get(cls.neighbor, count_hit=False)
            if neighbor_rec is not None and \
                    getattr(neighbor_rec.model, "confident", False):
                plan = _Plan("classifier", neighbor_rec.model,
                             neighbor_rec.candidate, neighbor=cls.neighbor,
                             profiled=fresh, cache_hits=hits,
                             **flags["adaptive"])
            else:
                sel = select_like(self.catalog, self.history, cls.neighbor)
                if sel is not None:
                    plan = _Plan("classifier", None, None,
                                 neighbor=cls.neighbor,
                                 neighbor_selection=sel,
                                 profiled=fresh, cache_hits=hits,
                                 **flags["adaptive"])
        if plan is None:
            plan = _Plan("baseline", None, None,
                         profiled=fresh, cache_hits=hits,
                         **flags["adaptive"])
        with self._lock:
            if plan.source == "classifier":
                self.stats.classifier_fallbacks += 1
            else:
                self.stats.baseline_fallbacks += 1
        # cache only fully-profiled negative outcomes: a plan cut short by
        # the budget reflects a transient denial, not a property of the
        # job, and must not stick once the budget recovers
        if not plan.budget_exhausted:
            with self._plan_lock:
                self._plan_cache[plan_key] = plan
                self._plan_cache.move_to_end(plan_key)
                while len(self._plan_cache) > self._cache_cap:
                    self._plan_cache.popitem(last=False)
        return plan

    def _measure_and_fit(self, sig: str, req: AllocationRequest,
                         sizes: List[float]):
        """Profile a ladder (adaptively or fixed) and fit the zoo over
        whatever points materialized. Returns (sizes, mems, fit, flags)."""
        adaptive = req.adaptive if req.adaptive is not None else self.adaptive
        aflags = {"early_stop": False, "escalated": False,
                  "budget_exhausted": False}
        if adaptive:
            ap = self._make_scheduler().run(sizes, req.full_size,
                                            self._point_fn(sig, req))
            aflags = {"early_stop": ap.early_stop,
                      "escalated": ap.escalated,
                      "budget_exhausted": ap.budget_exhausted}
            with self._lock:
                self.stats.adaptive_plans += 1
                self.stats.early_stops += int(ap.early_stop)
                self.stats.escalations += int(ap.escalated)
                self.stats.budget_denied += int(ap.budget_exhausted)
                self.stats.points_saved += max(0, len(sizes)
                                               - ap.total_points)
            return (ap.sizes, ap.mems, ap.fit,
                    {"fresh": ap.points, "hits": ap.cache_hits,
                     "walls": [r.wall_s for r in ap.results],
                     "adaptive": aflags})

        results, fresh, hits, exhausted = self._profile_ladder(sig, req,
                                                               sizes)
        got = [(s, r) for s, r in zip(sizes, results) if r is not None]
        used = [s for s, _ in got]
        mems = [r.job_mem_bytes for _, r in got]
        walls = [r.wall_s for _, r in got]
        aflags["budget_exhausted"] = exhausted
        if exhausted:
            with self._lock:
                self.stats.budget_denied += 1
        zoo = fit_zoo(used, mems, self.candidates)
        return used, mems, zoo, {"fresh": fresh, "hits": hits,
                                 "walls": walls, "adaptive": aflags}

    def _point_fn(self, sig: str, req: AllocationRequest):
        """Profile-point callback for the scheduler/executor, carrying a
        `.peek` so budget gates can serve cached points for free."""
        def pp(s: float) -> Tuple[ProfileResult, bool]:
            return self._profile_point(sig, req, s)
        pp.peek = lambda s: self._lookup_point(sig, s)
        return pp

    def _lookup_point(self, sig: str, s: float) -> Optional[ProfileResult]:
        """Cache-hierarchy lookup only (LRU -> shared store), no profiling.
        Thread-safe; counts hits."""
        key = (sig, float(s))
        with self._lock:
            cached = self._cache.get(key)
            if cached is not None:
                self._cache.move_to_end(key)
                self.stats.cache_hits += 1
        if cached is not None:
            return cached
        if self.store is not None:
            stored = self.store.get(sig, s)
            if stored is not None:
                with self._lock:
                    self.stats.store_hits += 1
                    self.stats.cache_hits += 1
                    self._cache_put_locked(key, stored)
                return stored
        return None

    def _profile_point(self, sig: str, req: AllocationRequest,
                       s: float) -> Tuple[ProfileResult, bool]:
        """One ladder point: cache hierarchy first, fresh profile run on a
        miss (recorded in LRU + store). Returns (result, fresh)."""
        cached = self._lookup_point(sig, s)
        if cached is not None:
            return cached, False
        r = req.profile_at(s)
        with self._lock:
            self.stats.profile_calls += 1
            self._cache_put_locked((sig, float(s)), r)
        if self.store is not None:
            try:
                self.store.put(sig, s, r)
            except Exception:
                pass                    # a write-through failure costs a
                                        # future re-profile, never the plan
        return r, True

    def _cache_put_locked(self, key: Tuple[str, float],
                          r: ProfileResult) -> None:
        self._cache[key] = r
        self._cache.move_to_end(key)
        while len(self._cache) > self._cache_cap:
            self._cache.popitem(last=False)

    def _profile_ladder(self, sig: str, req: AllocationRequest,
                        sizes: Sequence[float]
                        ) -> Tuple[List[Optional[ProfileResult]], int, int,
                                   bool]:
        """Fixed ladder: all points, concurrently when an executor is
        configured, each *fresh* run gated by the budget (cached points
        are always free). Returns results aligned with `sizes` (None =
        budget denial), fresh count, hit count, and whether the budget
        denied anything."""
        pp = self._point_fn(sig, req)
        if self.executor is not None:
            rows = self.executor.profile_ladder(sizes, pp,
                                                budget=self.budget)
            results = [r for _s, r, _f in rows]
            fresh = sum(1 for _s, r, f in rows if r is not None and f)
            hits = sum(1 for _s, r, f in rows if r is not None and not f)
            return results, fresh, hits, any(r is None for r in results)

        results: List[Optional[ProfileResult]] = []
        fresh = hits = 0
        exhausted = False
        for s in sizes:
            cached = pp.peek(s)
            if cached is not None:
                hits += 1
                results.append(cached)
                continue
            if self.budget is not None and not self.budget.try_spend():
                results.append(None)
                exhausted = True
                continue
            r, was_fresh = pp(s)
            if was_fresh:
                fresh += 1
                if self.budget is not None:
                    self.budget.charge(r.wall_s)
            else:
                hits += 1       # raced with a concurrent group's profile
                if self.budget is not None:
                    self.budget.refund()
            results.append(r)
        return results, fresh, hits, exhausted

    def _respond(self, plan: _Plan, req: AllocationRequest,
                 wall: float) -> AllocationResponse:
        leeway = req.leeway if req.leeway is not None else self.leeway
        if plan.model is not None:
            req_gib = plan.model.requirement(req.full_size, leeway) / GiB
            sel = select_crispy(self.catalog, self.history, req_gib,
                                overhead_per_node_gib=self.overhead,
                                exclude_job=req.job)
        elif plan.neighbor_selection is not None:
            req_gib = 0.0
            sel = plan.neighbor_selection
        else:
            req_gib = 0.0
            sel = select_crispy(self.catalog, self.history, 0.0,
                                overhead_per_node_gib=self.overhead,
                                exclude_job=req.job)
        return AllocationResponse(req.job, req.sig, plan.source,
                                  plan.candidate, plan.model, req_gib, sel,
                                  plan.neighbor, plan.profiled,
                                  plan.cache_hits, wall, plan.early_stop,
                                  plan.escalated, plan.budget_exhausted)
