"""AllocationService: the unified pipeline behind a batched service front.

All allocation *decisions* — ladder resolution, point acquisition and
placement, model fitting, requirement extrapolation, config selection,
the registry/zoo/classifier/baseline fallback chain — live in
`repro.pipeline.AllocationPipeline` (one staged path shared with the
one-shot `CrispyAllocator`; see repro/pipeline/__init__.py for the stage
diagram). This module contains ONLY the service concerns around it:

  submit() --+                          +--> pipeline.warm_start
             |   drain window (coalesce |      (registry hit: no profiling)
  submit() --+-> concurrent requests    +--> plan cache (negative outcomes
             |   into one batch, group  |      served without a refit)
  submit() --+   by job signature)      +--> pipeline.measure_plan
                                        |      (acquire -> fit -> fall back)
                                        +--> pipeline.finalize per request
                                             (extrapolate -> select)

plus the worker thread + futures, the cross-batch ProfileResult LRU the
pipeline's acquisition stage reads through, per-batch registry/store
refreshes and flushes, and wire-facing stats. Requests for the same job
signature that land in one batch share a single plan; repeats across
batches hit the model registry and never profile again.

Profiling orchestration (repro.profiling) and shared state (repro.state)
compose exactly as before:

  adaptive=True      placement-driven acquisition — the default
                     `placement="infogain"` profiles whichever size is
                     expected to shrink candidate-model disagreement at
                     full size the most and stops when further
                     measurement would not change the answer;
                     `placement="ladder"` keeps the PR-2 smallest-first
                     prefix with gap-midpoint escalation.
  budget=            a shared ProfilingBudget gates every fresh profile
                     run (adaptive or fixed) — the paper's ten-minute
                     envelope enforced service-wide. Cached/stored points
                     are NEVER charged.
  store=             a ProfileStore (over any repro.state backend) backs
                     the in-process LRU: points and calibrated anchors
                     profiled by *any* process are reused.
  executor=          a ProfilingExecutor profiles fixed ladders
                     point-concurrently and fans independent signature
                     groups of one batch out over its pool.
  backend=           a `repro.state.StateBackend`: the service builds its
                     ProfileStore and model registry over it unless
                     explicit `store=`/`registry=` override them, so N
                     service processes share points, anchors, models —
                     and ONE budget envelope when the ProfilingBudget
                     carries the same backend.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import OrderedDict
from concurrent.futures import Future, InvalidStateError
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.allocator.classifier import NearestJobClassifier
from repro.allocator.registry import ModelRegistry
from repro.core.catalog import ClusterConfig
from repro.core.history import ExecutionHistory
from repro.core.profiler import ProfileResult
from repro.core.selector import DEFAULT_OVERHEAD_GIB, Selection
from repro.telemetry import (MetricsRegistry, current_trace_context,
                             span_if)

GiB = 1024 ** 3


def _resolve(fut: Future, result=None, exc: Optional[Exception] = None):
    """Resolve a future the caller may have cancelled (or be cancelling
    concurrently) without letting InvalidStateError kill the worker."""
    if fut.cancelled():
        return
    try:
        if exc is not None:
            fut.set_exception(exc)
        else:
            fut.set_result(result)
    except InvalidStateError:       # cancelled between the check and the set
        pass


@dataclass
class AllocationRequest:
    job: str
    profile_at: Callable[[float], ProfileResult]
    full_size: float
    anchor: Optional[float] = None
    sizes: Optional[List[float]] = None
    signature: Optional[str] = None     # defaults to the job name
    leeway: Optional[float] = None      # overrides the service default
    adaptive: Optional[bool] = None     # overrides the service default
    placement: Optional[object] = None  # "infogain" | "ladder" | PointPlacer
    tags: Optional[Sequence[str]] = None    # Flora-style categorical tags
    objective: str = "cheapest_fit"     # | "min_cost" | "min_runtime"

    @property
    def sig(self) -> str:
        return self.signature if self.signature is not None else self.job

    @property
    def tags_key(self) -> Optional[frozenset]:
        """Canonical form of the tag palette for grouping/caching: tags
        can steer the classifier, so requests carrying different palettes
        must never share a plan."""
        return frozenset(self.tags) if self.tags is not None else None


@dataclass
class AllocationResponse:
    job: str
    signature: str
    source: str                  # registry | zoo | classifier | baseline
    candidate: Optional[str]     # winning model kind (None on baseline)
    model: Optional[object]
    requirement_gib: float
    selection: Selection
    neighbor: Optional[str] = None
    profiled: int = 0            # fresh profile_at calls for this plan
    cache_hits: int = 0          # ladder points served from the LRU/store
    wall_s: float = 0.0
    early_stop: bool = False     # adaptive schedule stopped before 5 points
    escalated: bool = False      # adaptive schedule spent extra points
    budget_exhausted: bool = False   # the budget denied at least one point
    placement: Optional[str] = None  # point-placement strategy (adaptive)
    store_hits: int = 0          # subset of cache_hits served by the store
    stage_walls: Optional[Dict[str, float]] = None   # per-stage seconds
                                 # (warm_start/acquire/fit/classify/
                                 # extrapolate/select); wire opt-in via
                                 # AllocationEndpoint.handle(include_trace=)
    objective: str = "cheapest_fit"  # what this request optimized for
    runtime_candidate: Optional[str] = None   # runtime model kind backing
                                 # a cost/runtime ranking (None without one)


# the wire-facing counter names; each is a `service.<name>` Counter on
# the service's MetricsRegistry
_STAT_FIELDS = (
    "requests", "batches", "profile_calls", "cache_hits", "registry_hits",
    "zoo_fits", "zoo_confident", "classifier_fallbacks",
    "baseline_fallbacks",
    "plan_cache_hits",           # unconfident repeats answered w/o refit
    "flush_errors",              # registry persistence failures survived
    "store_hits",                # ladder points served by the shared store
    "adaptive_plans",            # plans scheduled adaptively
    "early_stops",               # adaptive plans that stopped early
    "escalations",               # adaptive plans that spent extra points
    "points_saved",              # ladder points adaptive plans did not run
    "budget_denied",             # plans the budget cut short
    "runtime_fits",              # plans that fit a runtime companion model
    "runtime_confident",         # runtime fits that passed their gate
    "cost_objective_requests",   # requests asking min_cost / min_runtime
    "objective_fallbacks",       # of those, selections that degraded to
                                 # cheapest_fit (unconfident runtime model)
)


class ServiceStats:
    """Compatibility VIEW over the service's `service.*` counters in its
    MetricsRegistry. Attribute reads (`stats.requests`) fold the
    per-thread counter shards, so they always agree with
    `AllocationService.metrics()` — one thread-safe source of truth
    where two racing sets of `+=` (some outside the lock) used to drift.
    Read-only by construction: increments go through `inc()`, which the
    service owns. Over a disabled registry every field reads 0."""

    FIELDS = _STAT_FIELDS

    def __init__(self, telemetry: Optional[MetricsRegistry] = None):
        tel = telemetry if telemetry is not None else MetricsRegistry()
        object.__setattr__(self, "_counters",
                           {f: tel.counter("service." + f)
                            for f in _STAT_FIELDS})

    def inc(self, name: str, n: float = 1) -> None:
        self._counters[name].inc(n)

    def __getattr__(self, name: str):
        counters = object.__getattribute__(self, "_counters")
        if name in counters:
            return int(counters[name].value)
        raise AttributeError(name)

    def __setattr__(self, name: str, value) -> None:
        raise AttributeError(
            f"ServiceStats is a read-only view over MetricsRegistry "
            f"counters; cannot set {name!r}")

    @property
    def profile_hit_rate(self) -> float:
        total = self.profile_calls + self.cache_hits
        return self.cache_hits / total if total else 0.0

    def as_dict(self) -> Dict[str, int]:
        return {f: int(c.value) for f, c in self._counters.items()}


class _ProfileLRU:
    """Cross-batch ProfileResult LRU behind the pipeline's PointSource
    cache interface (get/put). Thread-safe AND lock-striped: fixed-ladder
    points and concurrent signature groups read through it from executor
    workers, and under a hot mixed batch a single global lock serializes
    every group on every point lookup — so entries are sharded by
    signature hash, each shard owning its own lock, OrderedDict, and a
    proportional slice of the capacity. LRU order is per-shard (a global
    order would need the global lock back), which approximates global
    LRU well when signatures spread across shards."""

    SHARDS = 16

    def __init__(self, cap: int, shards: int = SHARDS):
        self._nshards = max(1, min(int(shards), max(1, cap)))
        self._shard_cap = max(1, cap // self._nshards)
        self._shards = [
            (threading.Lock(), OrderedDict())
            for _ in range(self._nshards)]

    def _shard(self, signature: str):
        return self._shards[hash(signature) % self._nshards]

    def get(self, signature: str, size: float) -> Optional[ProfileResult]:
        key = (signature, float(size))
        lock, cache = self._shard(signature)
        with lock:
            r = cache.get(key)
            if r is not None:
                cache.move_to_end(key)
            return r

    def put(self, signature: str, size: float, result: ProfileResult,
            from_store: bool = False) -> None:
        key = (signature, float(size))
        lock, cache = self._shard(signature)
        with lock:
            cache[key] = result
            cache.move_to_end(key)
            while len(cache) > self._shard_cap:
                cache.popitem(last=False)


class _PlanCache:
    """Striped negative-outcome plan cache (see AllocationService: maps
    (sig, ladder, tags, objective, settings) -> unconfident plan). Same
    sharding
    rationale as _ProfileLRU — concurrent signature groups must not
    serialize on one lock — with the history-version invalidation kept
    PER SHARD: each shard remembers the history version it was filled
    under and self-clears lazily on its next access after a mutation,
    so invalidation needs no global barrier either."""

    SHARDS = 16

    def __init__(self, cap: int, hist_version, shards: int = SHARDS):
        self._nshards = max(1, min(int(shards), max(1, cap)))
        self._shard_cap = max(1, cap // self._nshards)
        self._shards = [
            [threading.Lock(), OrderedDict(), hist_version]
            for _ in range(self._nshards)]

    def _shard(self, plan_key: Tuple):
        # shard by signature (plan_key[0]): everything else in the key
        # only disambiguates within a signature
        return self._shards[hash(plan_key[0]) % self._nshards]

    def get(self, plan_key: Tuple, hist_version):
        shard = self._shard(plan_key)
        lock, cache, _ = shard
        with lock:
            if shard[2] != hist_version:
                cache.clear()
                shard[2] = hist_version
                return None
            plan = cache.get(plan_key)
            if plan is not None:
                cache.move_to_end(plan_key)
            return plan

    def put(self, plan_key: Tuple, plan, hist_version) -> None:
        shard = self._shard(plan_key)
        lock, cache, _ = shard
        with lock:
            if shard[2] != hist_version:
                cache.clear()
                shard[2] = hist_version
            cache[plan_key] = plan
            cache.move_to_end(plan_key)
            while len(cache) > self._shard_cap:
                cache.popitem(last=False)

    def clear(self) -> None:
        for shard in self._shards:
            lock, cache, _ = shard
            with lock:
                cache.clear()


class AllocationService:
    def __init__(self, catalog: List[ClusterConfig],
                 history: ExecutionHistory,
                 registry: Optional[ModelRegistry] = None,
                 classifier: Optional[NearestJobClassifier] = None,
                 candidates: Optional[Sequence] = None,
                 overhead_per_node_gib: float = DEFAULT_OVERHEAD_GIB,
                 leeway: float = 0.0,
                 profile_cache_size: int = 512,
                 batch_window_s: float = 0.005,
                 adaptive: bool = False,
                 placement="infogain",      # repro.pipeline point placement
                 budget=None,               # repro.profiling ProfilingBudget
                 store=None,                # repro.profiling ProfileStore
                 executor=None,             # repro.profiling ProfilingExecutor
                 backend=None,              # repro.state StateBackend
                 telemetry=None,            # repro.telemetry MetricsRegistry
                 sampler=None):             # warm-path sampling policy:
                                            # None|"adaptive"|"fixed"|int|obj
                                            # (repro.telemetry.sampling)
        self.catalog = catalog
        self.history = history
        self.backend = backend
        if backend is not None:
            # deferred import: repro.profiling imports allocator submodules
            from repro.profiling.store import (BackendModelRegistry,
                                               ProfileStore)
            if store is None:
                # write-behind: the worker flushes the batch's buffered
                # point/anchor rows as ONE backend frame per batch (see
                # _process_batch) instead of one round trip per point
                store = ProfileStore(backend=backend, namespace="profiles",
                                     write_behind=True)
            if registry is None:
                registry = BackendModelRegistry(backend,
                                                namespace="registry")
        self.registry = registry if registry is not None else ModelRegistry()
        self.classifier = classifier if classifier is not None \
            else NearestJobClassifier()
        self.budget = budget
        self.store = store
        self.executor = executor
        self.batch_window_s = batch_window_s
        self.adaptive = adaptive
        # per-SERVICE registry by default (not the process default): two
        # services in one process must not sum each other's counters.
        # Pass an explicit registry to share one (e.g. with a budget).
        self.telemetry = telemetry if telemetry is not None \
            else MetricsRegistry()
        self.stats = ServiceStats(self.telemetry)
        self._h_batch = self.telemetry.histogram(
            "service.batch.size",
            buckets=(1, 2, 3, 4, 6, 8, 12, 16, 24, 32, 48, 64, 96, 128,
                     192, 256))
        self._h_queue = self.telemetry.histogram(
            "service.queue_wait.seconds")
        self._h_request = self.telemetry.histogram(
            "service.request.seconds")
        self._cache = _ProfileLRU(profile_cache_size)

        # the ONE decision path (deferred import: repro.pipeline imports
        # allocator submodules)
        from repro.pipeline import AllocationPipeline
        self.pipeline = AllocationPipeline(
            catalog, history, registry=self.registry,
            classifier=self.classifier, candidates=candidates,
            overhead_per_node_gib=overhead_per_node_gib, leeway=leeway,
            adaptive=adaptive, placement=placement, budget=budget,
            store=store, executor=executor, cache=self._cache,
            defer_registry_save=True,
            refresh_store=False,    # _process_batch refreshes once per batch
            telemetry=self.telemetry, sampler=sampler)

        self._cache_cap = profile_cache_size
        # negative-outcome cache: (sig, ladder, tags, settings) ->
        # unconfident plan,
        # so a noisy job resubmitted N times doesn't redo the zoo LOOCV
        # fit and classifier scan N times. Cleared whenever the observable
        # world changes (new signature observed / new model registered),
        # because either can turn a baseline outcome into a classifier
        # one. Lock-striped (_PlanCache): with an executor, a batch's
        # signature groups plan concurrently and must not serialize on
        # a single cache lock.
        self._plan_cache = _PlanCache(profile_cache_size, history.version)
        self._lock = threading.Lock()
        self._cv = threading.Condition(self._lock)
        # pending tuples carry the submitter's trace context: contextvars
        # do not cross threads, so the worker must be handed the token
        # explicitly to open its spans inside the caller's trace
        self._pending: List[Tuple[AllocationRequest, Future, float,
                                  Optional[Dict]]] = []
        self._worker: Optional[threading.Thread] = None
        self._closed = False

        # warm the classifier from persisted registry records: a restarted
        # service classifies against every CONFIDENT signature it ever
        # registered (gate-failing ladders live only in memory and are
        # re-observed as their jobs resubmit)
        for rec in self.registry.records():
            self.classifier.observe(rec.signature, rec.sizes, rec.mems)

    def _shared_backend(self):
        for b in (self.backend, getattr(self.store, "backend", None),
                  getattr(self.registry, "backend", None),
                  getattr(self.budget, "backend", None)):
            if b is not None:
                return b
        return None

    @property
    def backend_kind(self) -> Optional[str]:
        """Kind of the shared-state backend this service operates over
        ("memory" | "file" | "daemon"), from whichever shared component
        carries one; None for a fully process-local service."""
        return getattr(self._shared_backend(), "kind", None)

    @property
    def backend_transport(self) -> Optional[str]:
        """Transport of a daemon backend ("unix" | "tcp"); None for
        local backends — the monitoring signal that distinguishes a
        co-located daemon from a multi-host one."""
        return getattr(self._shared_backend(), "transport", None)

    @property
    def backend_address(self) -> Optional[str]:
        """Address a daemon backend connects to (unix path or host:port);
        None for local backends."""
        return getattr(self._shared_backend(), "address", None)

    @property
    def backend_shards(self) -> Optional[List[Dict]]:
        """Shard topology of a sharded backend: one {"name", "kind",
        "address", "standby"} descriptor per shard (see
        repro.state.sharding.ShardedBackend.topology); None over a
        single backend."""
        topo = getattr(self._shared_backend(), "topology", None)
        if not callable(topo):
            return None
        return topo().get("shards")

    def metrics(self) -> Dict:
        """Snapshot of every instrument on this service's registry —
        the `service.*` counters/histograms plus whatever the pipeline,
        acquisition, and (if it shares the registry) budget recorded.
        See repro.telemetry for the map."""
        return self.telemetry.snapshot()

    # -- public -------------------------------------------------------------
    def submit(self, req: AllocationRequest) -> "Future[AllocationResponse]":
        fut: Future = Future()
        ctx = current_trace_context()   # captured HERE, in the caller's
        with self._cv:                  # thread; None when untraced
            if self._closed:
                raise RuntimeError("AllocationService is closed")
            self._pending.append((req, fut, time.monotonic(), ctx))
            self._ensure_worker_locked()
            self._cv.notify()
        return fut

    def allocate(self, req: AllocationRequest,
                 timeout: Optional[float] = None) -> AllocationResponse:
        return self.submit(req).result(timeout)

    def allocate_many(self, reqs: Sequence[AllocationRequest],
                      timeout: Optional[float] = None
                      ) -> List[AllocationResponse]:
        futs = [self.submit(r) for r in reqs]
        return [f.result(timeout) for f in futs]

    def close(self) -> None:
        with self._cv:
            self._closed = True
            self._cv.notify_all()
        if self._worker is not None:
            self._worker.join(timeout=5.0)
        # durability backstop for write-behind rows + deferred puts
        self._flush_shared_state()

    def __enter__(self) -> "AllocationService":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- worker -------------------------------------------------------------
    def _ensure_worker_locked(self) -> None:
        if self._worker is None or not self._worker.is_alive():
            self._worker = threading.Thread(target=self._run, daemon=True)
            self._worker.start()

    def _run(self) -> None:
        while True:
            # under sustained load a batch's writes are carried by the
            # NEXT batch's sync frame (see _process_batch); when the
            # queue drains, flush now so siblings see the last batch's
            # points/models without waiting for more traffic. Outside
            # the lock: a flush round trip must not block submitters.
            with self._cv:
                idle = not self._pending and not self._closed
            if idle:
                self._flush_shared_state()
            with self._cv:
                while not self._pending and not self._closed:
                    self._cv.wait()
                if not self._pending and self._closed:
                    return
            # coalesce: give concurrent submitters a window to land in the
            # same batch so same-signature ladders dedup to one plan
            if self.batch_window_s > 0:
                time.sleep(self.batch_window_s)
            with self._cv:
                batch, self._pending = self._pending, []
            if batch:
                self._process_batch(batch)

    def _flush_shared_state(self) -> None:
        """Push buffered write-behind rows and deferred registry models
        to the backend. A persistence failure (disk full, daemon down)
        must never kill the worker — rows stay queued / models stay in
        memory and the next flush retries."""
        flush_writes = getattr(self.store, "flush_writes", None)
        if flush_writes is not None:
            try:
                flush_writes()
            except Exception:
                self.stats.inc("flush_errors")
        try:
            self.registry.flush()
        except Exception:
            self.stats.inc("flush_errors")

    def _preq(self, req: AllocationRequest):
        """The pipeline-facing view of a wire request."""
        from repro.pipeline import PipelineRequest
        return PipelineRequest(req.job, req.profile_at, req.full_size,
                               anchor=req.anchor, sizes=req.sizes,
                               signature=req.signature, leeway=req.leeway,
                               adaptive=req.adaptive,
                               placement=req.placement, tags=req.tags,
                               objective=req.objective)

    def _settings_key(self, req: AllocationRequest):
        """Resolved acquisition settings for grouping/plan-cache keys: an
        explicit adaptive=/placement= override produces different points
        than the service defaults, so such requests must never share (or
        be served) a plan computed under other settings."""
        adaptive = req.adaptive if req.adaptive is not None \
            else self.adaptive
        if not adaptive:
            return (False, None)
        placement = req.placement if req.placement is not None \
            else self.pipeline.placement
        # a placer INSTANCE keys by identity (two instances of one class
        # can carry different knobs, so a shared name would alias them;
        # holding the instance in the key also keeps its id from being
        # recycled under a cached plan). Placement names key by value.
        return (True, placement)

    def _process_batch(
            self,
            batch: List[Tuple[AllocationRequest, Future, float,
                              Optional[Dict]]]) -> None:
        self.stats.inc("batches")
        self.stats.inc("requests", len(batch))
        self._h_batch.observe(len(batch))
        now = time.monotonic()
        for _req, _fut, t_sub, _ctx in batch:
            self._h_queue.observe(now - t_sub)
        # batch-level backend work (refresh below, flush at the end) joins
        # the FIRST traced requester's trace — the same convention as the
        # shared planning work — so coalescing round trips out of the
        # per-request path doesn't also detach them from every trace
        batch_ctx = next((ctx for _r, _f, _t, ctx in batch
                          if ctx is not None), None)
        # one round trip per batch: the PREVIOUS batch's buffered
        # point/anchor rows and deferred registry models ride at the
        # front of this batch's refresh frame (batch frames read their
        # own writes), then sibling processes' work is pulled in —
        # profile points / anchors from the shared store, models from a
        # shared registry (repro.profiling.store.sync_views). A failure
        # re-queues the writes and leaves the views stale — both safe.
        try:
            from repro.profiling.store import sync_views
            with span_if(batch_ctx is not None, "service.refresh",
                         parent=batch_ctx):
                sync_views(self.store, self.registry)
        except Exception:
            pass                            # stale view is still correct
        # group by (signature, ladder, tags, objective, acquisition
        # settings): same-signature requests share one plan only when
        # they ask for the same ladder, carry the same tag palette, the
        # same selection objective AND resolve to the same
        # adaptive/placement settings — coalescing never silently
        # overrides an explicit sizes/anchor, a tag-steered
        # classification, a cost objective, or a per-request acquisition
        # override
        groups: "OrderedDict[Tuple, " \
                "List[Tuple[AllocationRequest, Future, float, " \
                "Optional[Dict]]]" = \
            OrderedDict()
        for req, fut, t_sub, ctx in batch:
            ladder = self.pipeline.ladder_for(self._preq(req))
            groups.setdefault(
                (req.sig, ladder, req.tags_key, req.objective,
                 self._settings_key(req)),
                []).append((req, fut, t_sub, ctx))

        def handle_group(entry) -> None:
            (sig, ladder, _tags, _objective, _settings), items = entry
            live = [(req, fut, ts, ctx) for req, fut, ts, ctx in items
                    if not fut.cancelled()]
            if not live:                    # whole group cancelled: don't
                return                      # profile for nobody
            t0 = time.monotonic()
            # the shared planning work joins the FIRST traced requester's
            # trace (coalesced siblings get their own service.respond
            # spans below); untraced groups open no span at all, exactly
            # the pre-tracing behavior
            ctx0 = next((ctx for _r, _f, _t, ctx in live
                         if ctx is not None), None)
            try:
                with span_if(ctx0 is not None, "service.plan",
                             parent=ctx0, signature=sig,
                             coalesced=len(live)):
                    plan = self._plan(sig, ladder, live[0][0])
            except Exception as e:          # a failing profile_at fails its
                for _, fut, _ts, _ctx in live:  # group, never the batch
                    _resolve(fut, exc=e)
                return
            wall = time.monotonic() - t0
            for req, fut, ts, ctx in live:
                try:
                    with span_if(ctx is not None, "service.respond",
                                 parent=ctx, job=req.job):
                        resp = self._respond(plan, req, wall)
                except Exception as e:
                    _resolve(fut, exc=e)
                    continue
                _resolve(fut, result=resp)
                # submit -> answer, queue wait and batching included
                self._h_request.observe(time.monotonic() - ts)

        entries = list(groups.items())
        if self.executor is not None and len(entries) > 1:
            # independent signatures plan (and profile) concurrently;
            # handle_group resolves its own futures and never raises
            self.executor.map_tasks(handle_group, entries)
        else:
            for entry in entries:
                handle_group(entry)
        # NO flush here: whatever this batch wrote stays buffered (rows)
        # or deferred (registry models) and rides in the NEXT batch's
        # sync frame — or is pushed by the worker's idle-time
        # _flush_shared_state the moment the queue drains. Either way
        # the loaded steady state is one wire frame per batch.

    # -- planning: pipeline calls + caches + stats --------------------------
    def _plan(self, sig: str, ladder: Tuple[float, ...],
              req: AllocationRequest):
        plan = self.pipeline.warm_start(sig)
        if plan is not None:
            self.stats.inc("registry_hits")
            return plan

        plan_key = (sig, ladder, req.tags_key, req.objective,
                    self._settings_key(req))
        # classifier/baseline plans freeze history-derived selections,
        # so a history mutation invalidates the negative cache (each
        # shard self-clears on its next access at the new version)
        cached_plan = self._plan_cache.get(plan_key, self.history.version)
        if cached_plan is not None:
            self.stats.inc("plan_cache_hits")
            # this request did no profiling; don't report the
            # original's counters or adaptive-schedule flags
            return dataclasses.replace(cached_plan, profiled=0,
                                       cache_hits=0, store_hits=0,
                                       early_stop=False,
                                       escalated=False,
                                       budget_exhausted=False)

        plan = self.pipeline.measure_plan(self._preq(req), ladder)
        self._count_plan(plan)
        if plan.newly_observed or plan.registered:
            # a new neighbor (or a new confident model) may rescue
            # previously-cached negative outcomes
            self._plan_cache.clear()
        # cache only fully-profiled negative outcomes: a plan cut short by
        # the budget reflects a transient denial, not a property of the
        # job, and must not stick once the budget recovers
        if plan.source in ("classifier", "baseline") \
                and not plan.budget_exhausted:
            self._plan_cache.put(plan_key, plan, self.history.version)
        return plan

    def _count_plan(self, plan) -> None:
        """Map one measured plan onto the wire-facing counters (no lock:
        the counters themselves are thread-safe)."""
        s = self.stats
        s.inc("zoo_fits", int(plan.fit_ran))
        s.inc("zoo_confident", int(plan.registered))
        if plan.source == "classifier":
            s.inc("classifier_fallbacks")
        elif plan.source == "baseline":
            s.inc("baseline_fallbacks")
        s.inc("profile_calls", plan.profiled)
        s.inc("cache_hits", plan.cache_hits)
        s.inc("store_hits", plan.store_hits)
        if plan.adaptive:
            s.inc("adaptive_plans")
            s.inc("early_stops", int(plan.early_stop))
            s.inc("escalations", int(plan.escalated))
            s.inc("points_saved", max(0, plan.base_points
                                      - plan.total_points))
        s.inc("budget_denied", int(plan.budget_exhausted))
        if plan.runtime_fit is not None:
            s.inc("runtime_fits")
            s.inc("runtime_confident",
                  int(getattr(plan.runtime_fit, "confident", False)))

    def _respond(self, plan, req: AllocationRequest,
                 wall: float) -> AllocationResponse:
        trace = self.pipeline.finalize(plan, self._preq(req), wall)
        p = trace.plan
        sel = trace.selection
        if req.objective != "cheapest_fit":
            self.stats.inc("cost_objective_requests")
            self.stats.inc("objective_fallbacks",
                           int(getattr(sel, "objective_fell_back", False)))
        return AllocationResponse(req.job, req.sig, p.source, p.candidate,
                                  p.model, trace.requirement_gib,
                                  sel, p.neighbor, p.profiled,
                                  p.cache_hits, wall, p.early_stop,
                                  p.escalated, p.budget_exhausted,
                                  p.placement, p.store_hits,
                                  dict(trace.stage_walls),
                                  objective=req.objective,
                                  runtime_candidate=p.runtime_candidate)
