"""AllocationService: allocation as a servable, stateful subsystem.

Request lifecycle (one worker thread, many submitters):

  submit() --+                          +--> registry hit: skip profiling
             |   drain window (coalesce |
  submit() --+-> concurrent requests    +--> LRU-cached ladder profile
             |   into one batch, group  |      -> model-zoo fit (LOOCV)
  submit() --+   by job signature)      |      -> confident: persist model
                                        |      -> else: nearest-job
                                        |         classifier transfer
                                        +--> per-request config selection

Requests for the same job signature that land in one batch share a single
profiling ladder (dedup); repeats across batches hit the model registry and
never profile again; distinct requests that need the same (signature, size)
sample hit the ProfileResult LRU. Per-profile work is therefore done at
most once per (signature, size) while the cache holds.

Fallback chain when no zoo candidate is confident — Flora-style (see
classifier.py): transfer the nearest observed neighbor's registered model,
else the neighbor's best historical config, else the paper's BFA baseline
(requirement 0). Profiled ladders are always `observe`d by the classifier,
so even gate-failing jobs contribute to future classifications.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import OrderedDict
from concurrent.futures import Future, InvalidStateError
from dataclasses import dataclass
from typing import Callable, List, Optional, Sequence, Tuple

from repro.allocator.classifier import NearestJobClassifier
from repro.allocator.model_zoo import fit_zoo
from repro.allocator.registry import ModelRegistry
from repro.core.catalog import ClusterConfig
from repro.core.history import ExecutionHistory
from repro.core.profiler import ProfileResult
from repro.core.sampling import ladder_from_anchor
from repro.core.selector import (DEFAULT_OVERHEAD_GIB, Selection,
                                 select_crispy, select_like)

GiB = 1024 ** 3


def _resolve(fut: Future, result=None, exc: Optional[Exception] = None):
    """Resolve a future the caller may have cancelled (or be cancelling
    concurrently) without letting InvalidStateError kill the worker."""
    if fut.cancelled():
        return
    try:
        if exc is not None:
            fut.set_exception(exc)
        else:
            fut.set_result(result)
    except InvalidStateError:       # cancelled between the check and the set
        pass


@dataclass
class AllocationRequest:
    job: str
    profile_at: Callable[[float], ProfileResult]
    full_size: float
    anchor: Optional[float] = None
    sizes: Optional[List[float]] = None
    signature: Optional[str] = None     # defaults to the job name
    leeway: Optional[float] = None      # overrides the service default

    @property
    def sig(self) -> str:
        return self.signature if self.signature is not None else self.job


@dataclass
class AllocationResponse:
    job: str
    signature: str
    source: str                  # registry | zoo | classifier | baseline
    candidate: Optional[str]     # winning model kind (None on baseline)
    model: Optional[object]
    requirement_gib: float
    selection: Selection
    neighbor: Optional[str] = None
    profiled: int = 0            # fresh profile_at calls for this plan
    cache_hits: int = 0          # ladder points served from the LRU
    wall_s: float = 0.0


@dataclass
class ServiceStats:
    requests: int = 0
    batches: int = 0
    profile_calls: int = 0
    cache_hits: int = 0
    registry_hits: int = 0
    zoo_fits: int = 0
    zoo_confident: int = 0
    classifier_fallbacks: int = 0
    baseline_fallbacks: int = 0
    plan_cache_hits: int = 0     # unconfident repeats answered w/o refit
    flush_errors: int = 0        # registry persistence failures survived

    @property
    def profile_hit_rate(self) -> float:
        total = self.profile_calls + self.cache_hits
        return self.cache_hits / total if total else 0.0


@dataclass
class _Plan:
    """Per-signature outcome shared by every request in a batch group."""
    source: str
    model: Optional[object]
    candidate: Optional[str]
    neighbor: Optional[str] = None
    neighbor_selection: Optional[Selection] = None
    profiled: int = 0
    cache_hits: int = 0


class AllocationService:
    def __init__(self, catalog: List[ClusterConfig],
                 history: ExecutionHistory,
                 registry: Optional[ModelRegistry] = None,
                 classifier: Optional[NearestJobClassifier] = None,
                 candidates: Optional[Sequence] = None,
                 overhead_per_node_gib: float = DEFAULT_OVERHEAD_GIB,
                 leeway: float = 0.0,
                 profile_cache_size: int = 512,
                 batch_window_s: float = 0.005):
        self.catalog = catalog
        self.history = history
        self.registry = registry if registry is not None else ModelRegistry()
        self.classifier = classifier if classifier is not None \
            else NearestJobClassifier()
        self.candidates = candidates
        self.overhead = overhead_per_node_gib
        self.leeway = leeway
        self.batch_window_s = batch_window_s
        self.stats = ServiceStats()

        self._cache: "OrderedDict[Tuple[str, float], ProfileResult]" = \
            OrderedDict()
        self._cache_cap = profile_cache_size
        # negative-outcome cache: (sig, ladder) -> unconfident _Plan, so a
        # noisy job resubmitted N times doesn't redo the zoo LOOCV fit and
        # classifier scan N times. Cleared whenever the observable world
        # changes (new signature observed / new model registered), because
        # either can turn a baseline outcome into a classifier one.
        # Worker-thread-only state: no lock needed.
        self._plan_cache: "OrderedDict[Tuple[str, Tuple[float, ...]], _Plan]" \
            = OrderedDict()
        self._plan_cache_hist_version = history.version
        self._lock = threading.Lock()
        self._cv = threading.Condition(self._lock)
        self._pending: List[Tuple[AllocationRequest, Future]] = []
        self._worker: Optional[threading.Thread] = None
        self._closed = False

        # warm the classifier from persisted registry records: a restarted
        # service classifies against every CONFIDENT signature it ever
        # registered (gate-failing ladders live only in memory and are
        # re-observed as their jobs resubmit)
        for rec in self.registry.records():
            self.classifier.observe(rec.signature, rec.sizes, rec.mems)

    # -- public -------------------------------------------------------------
    def submit(self, req: AllocationRequest) -> "Future[AllocationResponse]":
        fut: Future = Future()
        with self._cv:
            if self._closed:
                raise RuntimeError("AllocationService is closed")
            self._pending.append((req, fut))
            self._ensure_worker_locked()
            self._cv.notify()
        return fut

    def allocate(self, req: AllocationRequest,
                 timeout: Optional[float] = None) -> AllocationResponse:
        return self.submit(req).result(timeout)

    def allocate_many(self, reqs: Sequence[AllocationRequest],
                      timeout: Optional[float] = None
                      ) -> List[AllocationResponse]:
        futs = [self.submit(r) for r in reqs]
        return [f.result(timeout) for f in futs]

    def close(self) -> None:
        with self._cv:
            self._closed = True
            self._cv.notify_all()
        if self._worker is not None:
            self._worker.join(timeout=5.0)
        try:
            self.registry.flush()   # durability backstop for deferred puts
        except Exception:
            self.stats.flush_errors += 1

    def __enter__(self) -> "AllocationService":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- worker -------------------------------------------------------------
    def _ensure_worker_locked(self) -> None:
        if self._worker is None or not self._worker.is_alive():
            self._worker = threading.Thread(target=self._run, daemon=True)
            self._worker.start()

    def _run(self) -> None:
        while True:
            with self._cv:
                while not self._pending and not self._closed:
                    self._cv.wait()
                if not self._pending and self._closed:
                    return
            # coalesce: give concurrent submitters a window to land in the
            # same batch so same-signature ladders dedup to one profile run
            if self.batch_window_s > 0:
                time.sleep(self.batch_window_s)
            with self._cv:
                batch, self._pending = self._pending, []
            if batch:
                self._process_batch(batch)

    def _process_batch(self,
                       batch: List[Tuple[AllocationRequest, Future]]) -> None:
        with self._lock:
            self.stats.batches += 1
            self.stats.requests += len(batch)
        # group by (signature, ladder): same-signature requests share one
        # profiling ladder only when they actually ask for the same ladder,
        # so coalescing never silently overrides an explicit sizes/anchor
        groups: "OrderedDict[Tuple[str, Tuple[float, ...]], " \
                "List[Tuple[AllocationRequest, Future]]]" = OrderedDict()
        for req, fut in batch:
            groups.setdefault((req.sig, self._ladder_of(req)),
                              []).append((req, fut))
        for (sig, _ladder), items in groups.items():
            live = [(req, fut) for req, fut in items if not fut.cancelled()]
            if not live:                    # whole group cancelled: don't
                continue                    # profile for nobody
            t0 = time.monotonic()
            try:
                plan = self._plan(sig, live[0][0])
            except Exception as e:          # a failing profile_at fails its
                for _, fut in live:         # group, never the whole batch
                    _resolve(fut, exc=e)
                continue
            wall = time.monotonic() - t0
            for req, fut in live:
                try:
                    resp = self._respond(plan, req, wall)
                except Exception as e:
                    _resolve(fut, exc=e)
                    continue
                _resolve(fut, result=resp)
        # one file rewrite for however many models this batch registered;
        # a persistence failure (disk full, read-only) must not kill the
        # worker — models stay in memory and the next flush retries
        try:
            self.registry.flush()
        except Exception:
            with self._lock:
                self.stats.flush_errors += 1

    # -- planning -----------------------------------------------------------
    @staticmethod
    def _ladder_of(req: AllocationRequest) -> Tuple[float, ...]:
        sizes = req.sizes if req.sizes is not None else \
            ladder_from_anchor(req.anchor if req.anchor is not None
                               else req.full_size * 0.01).sizes
        return tuple(float(s) for s in sizes)

    def _plan(self, sig: str, req: AllocationRequest) -> _Plan:
        rec = self.registry.get(sig)
        if rec is not None and getattr(rec.model, "confident", False):
            with self._lock:
                self.stats.registry_hits += 1
            return _Plan("registry", rec.model, rec.candidate)

        ladder = self._ladder_of(req)
        sizes = list(ladder)
        plan_key = (sig, ladder)
        # classifier/baseline plans freeze history-derived selections, so a
        # history mutation invalidates the whole negative cache
        hv = self.history.version
        if hv != self._plan_cache_hist_version:
            self._plan_cache.clear()
            self._plan_cache_hist_version = hv
        cached_plan = self._plan_cache.get(plan_key)
        if cached_plan is not None:
            self._plan_cache.move_to_end(plan_key)
            with self._lock:
                self.stats.plan_cache_hits += 1
            # this request did no profiling; don't report the original's
            return dataclasses.replace(cached_plan, profiled=0,
                                       cache_hits=0)

        results, fresh, hits = self._profile_ladder(sig, req, sizes)
        mems = [r.job_mem_bytes for r in results]
        zoo = fit_zoo(sizes, mems, self.candidates)
        with self._lock:
            self.stats.zoo_fits += 1
        # never discard profiling work: even gate-failing ladders feed
        # future nearest-job classifications
        newly_observed = not self.classifier.has(sig)
        self.classifier.observe(sig, sizes, mems)
        if newly_observed:
            self._plan_cache.clear()    # a new neighbor may rescue others

        if zoo.confident:
            self.registry.put(sig, zoo.model, zoo.candidate, sizes, mems,
                              defer_save=True)
            self._plan_cache.clear()    # its model may rescue others too
            with self._lock:
                self.stats.zoo_confident += 1
            return _Plan("zoo", zoo, zoo.candidate,
                         profiled=fresh, cache_hits=hits)

        plan = None
        cls = self.classifier.classify(sizes, mems, exclude=(sig,))
        if cls is not None:
            neighbor_rec = self.registry.get(cls.neighbor, count_hit=False)
            if neighbor_rec is not None and \
                    getattr(neighbor_rec.model, "confident", False):
                plan = _Plan("classifier", neighbor_rec.model,
                             neighbor_rec.candidate, neighbor=cls.neighbor,
                             profiled=fresh, cache_hits=hits)
            else:
                sel = select_like(self.catalog, self.history, cls.neighbor)
                if sel is not None:
                    plan = _Plan("classifier", None, None,
                                 neighbor=cls.neighbor,
                                 neighbor_selection=sel,
                                 profiled=fresh, cache_hits=hits)
        if plan is None:
            plan = _Plan("baseline", None, None,
                         profiled=fresh, cache_hits=hits)
        with self._lock:
            if plan.source == "classifier":
                self.stats.classifier_fallbacks += 1
            else:
                self.stats.baseline_fallbacks += 1
        self._plan_cache[plan_key] = plan
        self._plan_cache.move_to_end(plan_key)
        while len(self._plan_cache) > self._cache_cap:
            self._plan_cache.popitem(last=False)
        return plan

    def _profile_ladder(self, sig: str, req: AllocationRequest,
                        sizes: Sequence[float]
                        ) -> Tuple[List[ProfileResult], int, int]:
        results: List[ProfileResult] = []
        fresh = hits = 0
        for s in sizes:
            key = (sig, float(s))
            with self._lock:
                cached = self._cache.get(key)
                if cached is not None:
                    self._cache.move_to_end(key)
                    self.stats.cache_hits += 1
            if cached is not None:
                hits += 1
                results.append(cached)
                continue
            r = req.profile_at(s)
            fresh += 1
            results.append(r)
            with self._lock:
                self.stats.profile_calls += 1
                self._cache[key] = r
                self._cache.move_to_end(key)
                while len(self._cache) > self._cache_cap:
                    self._cache.popitem(last=False)
        return results, fresh, hits

    def _respond(self, plan: _Plan, req: AllocationRequest,
                 wall: float) -> AllocationResponse:
        leeway = req.leeway if req.leeway is not None else self.leeway
        if plan.model is not None:
            req_gib = plan.model.requirement(req.full_size, leeway) / GiB
            sel = select_crispy(self.catalog, self.history, req_gib,
                                overhead_per_node_gib=self.overhead,
                                exclude_job=req.job)
        elif plan.neighbor_selection is not None:
            req_gib = 0.0
            sel = plan.neighbor_selection
        else:
            req_gib = 0.0
            sel = select_crispy(self.catalog, self.history, 0.0,
                                overhead_per_node_gib=self.overhead,
                                exclude_job=req.job)
        return AllocationResponse(req.job, req.sig, plan.source,
                                  plan.candidate, plan.model, req_gib, sel,
                                  plan.neighbor, plan.profiled,
                                  plan.cache_hits, wall)
