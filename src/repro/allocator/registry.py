"""Persistent memory-model registry keyed by job signature.

The paper assumes jobs are too unique to recur — but a *service* sees the
same signature again and again (the same nightly ETL job over a growing
dataset). The registry closes that loop: once a job's memory model passes
its confidence gate, repeated allocation requests skip profiling entirely
and go straight to selection.

JSON-backed so a service restart keeps its models; each record also keeps
the training ladder (sizes, mems) so the nearest-job classifier can rebuild
its feature store from disk. Thread-safe: the AllocationService worker and
any direct callers share one lock.
"""
from __future__ import annotations

import json
import os
import threading
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

from repro.allocator.model_zoo import model_from_dict, model_to_dict

REGISTRY_VERSION = 1


@dataclass
class ModelRecord:
    signature: str
    model: object                   # fitted memory model (MODEL_KINDS)
    candidate: str                  # model kind that won selection
    sizes: List[float] = field(default_factory=list)
    mems: List[float] = field(default_factory=list)
    created_at: float = 0.0
    hits: int = 0
    # runtime companion model (MODEL_KINDS runtime_* kinds) + the ladder
    # wall times it was fit on; absent in records written by older versions
    runtime_model: Optional[object] = None
    runtime_candidate: Optional[str] = None
    walls: List[float] = field(default_factory=list)

    def to_dict(self) -> Dict:
        d = {"model": model_to_dict(self.model),
             "candidate": self.candidate,
             "sizes": list(self.sizes), "mems": list(self.mems),
             "created_at": self.created_at, "hits": self.hits}
        if self.runtime_model is not None:
            d["runtime_model"] = model_to_dict(self.runtime_model)
            d["runtime_candidate"] = self.runtime_candidate
        if self.walls:
            d["walls"] = list(self.walls)
        return d

    @classmethod
    def from_dict(cls, signature: str, d: Dict) -> "ModelRecord":
        rm = d.get("runtime_model")
        runtime_model = model_from_dict(rm) if rm else None
        return cls(signature, model_from_dict(d["model"]),
                   d.get("candidate", d["model"].get("kind", "linear")),
                   list(d.get("sizes", [])), list(d.get("mems", [])),
                   float(d.get("created_at", 0.0)), int(d.get("hits", 0)),
                   runtime_model=runtime_model,
                   runtime_candidate=d.get("runtime_candidate"),
                   walls=list(d.get("walls", [])))


class ModelRegistry:
    def __init__(self, path: Optional[str] = None, autosave: bool = True):
        self.path = path
        self.autosave = autosave
        self._lock = threading.RLock()
        self._records: Dict[str, ModelRecord] = {}
        self._dirty = False
        if path is not None and os.path.exists(path):
            self.load()

    def __len__(self) -> int:
        with self._lock:
            return len(self._records)

    def __contains__(self, signature: str) -> bool:
        with self._lock:
            return signature in self._records

    def signatures(self) -> List[str]:
        with self._lock:
            return sorted(self._records)

    def records(self) -> List[ModelRecord]:
        with self._lock:
            return list(self._records.values())

    def get(self, signature: str,
            count_hit: bool = True) -> Optional[ModelRecord]:
        with self._lock:
            rec = self._records.get(signature)
            if rec is not None and count_hit:
                rec.hits += 1
            return rec

    def put(self, signature: str, model, candidate: Optional[str] = None,
            sizes: Sequence[float] = (), mems: Sequence[float] = (),
            defer_save: bool = False, runtime_model=None,
            runtime_candidate: Optional[str] = None,
            walls: Sequence[float] = ()) -> ModelRecord:
        """Store a model. `defer_save=True` marks the registry dirty
        instead of rewriting the JSON file (which is O(all records)) —
        the AllocationService uses it and calls `flush()` once per batch."""
        if runtime_model is not None and runtime_candidate is None:
            runtime_candidate = getattr(runtime_model, "kind", None)
        rec = ModelRecord(signature, model,
                          candidate or getattr(model, "kind", "linear"),
                          list(sizes), list(mems), time.time(),
                          runtime_model=runtime_model,
                          runtime_candidate=runtime_candidate,
                          walls=list(walls))
        with self._lock:
            self._records[signature] = rec
            self._dirty = True
            if not defer_save and self.autosave and self.path is not None:
                self._save_locked(self.path)
        return rec

    def flush(self) -> None:
        """Write deferred puts to disk, one file rewrite for many puts."""
        with self._lock:
            if self._dirty and self.autosave and self.path is not None:
                self._save_locked(self.path)

    def evict(self, signature: str) -> bool:
        with self._lock:
            gone = self._records.pop(signature, None) is not None
            if gone:
                self._dirty = True
                if self.autosave and self.path is not None:
                    self._save_locked(self.path)
            return gone

    # -- persistence --------------------------------------------------------
    def save(self, path: Optional[str] = None) -> None:
        path = path or self.path
        if path is None:
            raise ValueError("ModelRegistry has no path to save to")
        with self._lock:
            self._save_locked(path)

    def _save_locked(self, path: str) -> None:
        payload = {"version": REGISTRY_VERSION,
                   "records": {sig: rec.to_dict()
                               for sig, rec in self._records.items()}}
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(payload, f)
        os.replace(tmp, path)       # atomic on POSIX: no torn reads
        self._dirty = False

    def load(self, path: Optional[str] = None) -> int:
        path = path or self.path
        if path is None:
            raise ValueError("ModelRegistry has no path to load from")
        with open(path) as f:
            payload = json.load(f)
        records = payload.get("records", {})
        with self._lock:
            self._records = {sig: ModelRecord.from_dict(sig, d)
                             for sig, d in records.items()}
            return len(self._records)
