"""Candidate-model zoo: richer memory models than the paper's single OLS.

Crispy (arXiv:2206.13852) fits exactly one model — linear with an R² > 0.99
train gate — and throws the profiling work away when the gate fails. Ruya
(arXiv:2211.04240) shows memory-aware modeling benefits from richer model
candidates. The zoo keeps the paper's linear fit as the *first, default*
candidate (so perfectly linear jobs reproduce seed behavior bit-for-bit)
and adds:

  loglinear  mem = a·ln(size) + b      (sub-linear growth, e.g. dedup-heavy)
  powerlaw   mem = c·size^p            (JVM object blow-up, super-linear)
  piecewise  two OLS segments          (phase changes: build side then probe)

Selection is leave-one-out cross-validation: every candidate is refit n
times with one sample held out and scored by normalized held-out RMSE. The
simplest candidate within 10% of the best score wins (linear first), so the
zoo never trades the paper's model away for an overfit one on linear data.

A `ZooFit` implements the same interface as `LinearMemoryModel` (`predict`
/ `confident` / `requirement`) and is therefore a drop-in for
`CrispyAllocator(fitter=zoo_fitter())` and `CrispyReport.model`. Its
confidence adds an out-of-sample gate on top of the paper's train-R² gate:
the winning candidate's LOOCV error must stay under `LOOCV_GATE` — the
natural generalization of "extrapolate only when the fit is near-perfect"
to model families with more free parameters.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import ClassVar, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.memory_model import (GatedMemoryModel, LinearMemoryModel,
                                     fit_memory_model, ols_fit, r2_score)

LOOCV_GATE = 0.05      # max normalized held-out RMSE to trust extrapolation


@dataclass
class LogLinearModel(GatedMemoryModel):
    a: float
    b: float
    r2: float
    n: int

    kind: ClassVar[str] = "loglinear"

    def predict(self, size: float) -> float:
        return self.a * math.log(max(size, 1e-300)) + self.b

    def to_dict(self) -> Dict:
        return {"kind": self.kind, "a": self.a, "b": self.b,
                "r2": self.r2, "n": self.n}

    @classmethod
    def from_dict(cls, d: Dict) -> "LogLinearModel":
        return cls(float(d["a"]), float(d["b"]), float(d["r2"]),
                   int(d["n"]))

    @classmethod
    def fit(cls, sizes: Sequence[float],
            mems: Sequence[float]) -> Optional["LogLinearModel"]:
        x = np.asarray(sizes, dtype=np.float64)
        y = np.asarray(mems, dtype=np.float64)
        if x.size < 2 or (x <= 0).any():
            return None
        coef = ols_fit(np.log(x), y)
        if coef is None:
            return None
        a, b = coef
        pred = a * np.log(x) + b
        return cls(a, b, r2_score(y, pred), int(x.size))


@dataclass
class PowerLawModel(GatedMemoryModel):
    c: float
    p: float
    r2: float
    n: int

    kind: ClassVar[str] = "powerlaw"

    def predict(self, size: float) -> float:
        s = max(size, 0.0)
        if s == 0.0 and self.p < 0:
            # limit of c*s^p as s->0+ with a decreasing fit: unbounded.
            # inf flows through requirement() into the selector's
            # nothing-fits fallback instead of raising ZeroDivisionError.
            return math.inf
        return self.c * s ** self.p

    def to_dict(self) -> Dict:
        return {"kind": self.kind, "c": self.c, "p": self.p,
                "r2": self.r2, "n": self.n}

    @classmethod
    def from_dict(cls, d: Dict) -> "PowerLawModel":
        return cls(float(d["c"]), float(d["p"]), float(d["r2"]),
                   int(d["n"]))

    @classmethod
    def fit(cls, sizes: Sequence[float],
            mems: Sequence[float]) -> Optional["PowerLawModel"]:
        x = np.asarray(sizes, dtype=np.float64)
        y = np.asarray(mems, dtype=np.float64)
        if x.size < 2 or (x <= 0).any() or (y <= 0).any():
            return None
        coef = ols_fit(np.log(x), np.log(y))
        if coef is None:
            return None
        p, lnc = coef
        c = math.exp(lnc)
        # score in the ORIGINAL space — log-space R² flatters large errors
        # at the top of the ladder, exactly where extrapolation leans
        pred = c * x ** p
        return cls(c, p, r2_score(y, pred), int(x.size))


@dataclass
class PiecewiseLinearModel(GatedMemoryModel):
    break_size: float
    left_slope: float
    left_intercept: float
    right_slope: float
    right_intercept: float
    r2: float
    n: int

    kind: ClassVar[str] = "piecewise"

    def predict(self, size: float) -> float:
        if size <= self.break_size:
            return self.left_slope * size + self.left_intercept
        # extrapolation always rides the right (large-size) segment
        return self.right_slope * size + self.right_intercept

    def to_dict(self) -> Dict:
        return {"kind": self.kind, "break_size": self.break_size,
                "left_slope": self.left_slope,
                "left_intercept": self.left_intercept,
                "right_slope": self.right_slope,
                "right_intercept": self.right_intercept,
                "r2": self.r2, "n": self.n}

    @classmethod
    def from_dict(cls, d: Dict) -> "PiecewiseLinearModel":
        return cls(float(d["break_size"]), float(d["left_slope"]),
                   float(d["left_intercept"]), float(d["right_slope"]),
                   float(d["right_intercept"]), float(d["r2"]),
                   int(d["n"]))

    @classmethod
    def fit(cls, sizes: Sequence[float],
            mems: Sequence[float]) -> Optional["PiecewiseLinearModel"]:
        x = np.asarray(sizes, dtype=np.float64)
        y = np.asarray(mems, dtype=np.float64)
        if x.size < 4:
            return None
        order = np.argsort(x)
        x, y = x[order], y[order]
        best = None
        for k in range(2, x.size - 1):          # >= 2 points per segment
            lo = ols_fit(x[:k], y[:k])
            hi = ols_fit(x[k:], y[k:])
            if lo is None or hi is None:
                continue
            brk = 0.5 * (x[k - 1] + x[k])
            pred = np.where(x <= brk,
                            lo[0] * x + lo[1], hi[0] * x + hi[1])
            r2 = r2_score(y, pred)
            if best is None or r2 > best[0]:
                best = (r2, brk, lo, hi)
        if best is None:
            return None
        r2, brk, lo, hi = best
        return cls(brk, lo[0], lo[1], hi[0], hi[1], r2, int(x.size))


class _LinearCandidate:
    """The paper's model, adapted to the candidate protocol."""
    kind = LinearMemoryModel.kind
    fit = staticmethod(fit_memory_model)


DEFAULT_CANDIDATES: Tuple = (_LinearCandidate, LogLinearModel,
                             PowerLawModel, PiecewiseLinearModel)


# --------------------------------------------------------------------------
# Runtime curves (arXiv:2306.03672): the same candidate families fit the
# per-point wall times the profiling ladder already measures. Runtime feeds
# a *ranking* (cost = price × predicted runtime), not a provisioning
# decision, so its train gate is looser than the paper's memory gate — a
# mis-ranked config wastes dollars, a mis-provisioned one OOMs.
# --------------------------------------------------------------------------

RUNTIME_R2_GATE = 0.95
RUNTIME_LOOCV_GATE = 0.10


class _RuntimeGate:
    """Mixin (MRO-first) relaxing the train gate for runtime candidates."""

    @property
    def confident(self) -> bool:
        return self.r2 > RUNTIME_R2_GATE


@dataclass
class RuntimeLinearModel(_RuntimeGate, LinearMemoryModel):
    kind: ClassVar[str] = "runtime_linear"

    @classmethod
    def fit(cls, sizes: Sequence[float],
            mems: Sequence[float]) -> "RuntimeLinearModel":
        m = fit_memory_model(sizes, mems)
        return cls(m.slope, m.intercept, m.r2, m.n)


@dataclass
class RuntimeLogLinearModel(_RuntimeGate, LogLinearModel):
    kind: ClassVar[str] = "runtime_loglinear"


@dataclass
class RuntimePowerLawModel(_RuntimeGate, PowerLawModel):
    kind: ClassVar[str] = "runtime_powerlaw"


@dataclass
class RuntimePiecewiseLinearModel(_RuntimeGate, PiecewiseLinearModel):
    kind: ClassVar[str] = "runtime_piecewise"


RUNTIME_CANDIDATES: Tuple = (RuntimeLinearModel, RuntimeLogLinearModel,
                             RuntimePowerLawModel,
                             RuntimePiecewiseLinearModel)

# kind -> class, for registry deserialization
MODEL_KINDS = {LinearMemoryModel.kind: LinearMemoryModel,
               LogLinearModel.kind: LogLinearModel,
               PowerLawModel.kind: PowerLawModel,
               PiecewiseLinearModel.kind: PiecewiseLinearModel,
               RuntimeLinearModel.kind: RuntimeLinearModel,
               RuntimeLogLinearModel.kind: RuntimeLogLinearModel,
               RuntimePowerLawModel.kind: RuntimePowerLawModel,
               RuntimePiecewiseLinearModel.kind: RuntimePiecewiseLinearModel}


def model_to_dict(model) -> Dict:
    return model.to_dict()


def model_from_dict(d: Dict):
    kind = d.get("kind")
    if kind not in MODEL_KINDS:
        raise ValueError(f"unknown memory-model kind {kind!r}")
    return MODEL_KINDS[kind].from_dict(d)


@dataclass
class ZooFit(GatedMemoryModel):
    """Best-candidate fit; drop-in for the LinearMemoryModel interface.
    Inherits the shared requirement clamp; `confident` tightens the train
    gate with the out-of-sample one."""
    model: object                    # the winning fitted candidate
    candidate: str                   # its kind
    scores: Dict[str, float]         # kind -> normalized LOOCV RMSE
    train_r2: Dict[str, float]       # kind -> train R²
    n: int
    loocv_gate: float = LOOCV_GATE
    fits: Optional[Dict[str, object]] = None   # kind -> fitted candidate
                                     # (all of them — the adaptive
                                     # scheduler's disagreement check
                                     # reads their full-size predictions
                                     # without refitting)

    @property
    def loocv_score(self) -> float:
        return self.scores.get(self.candidate, math.inf)

    @property
    def confident(self) -> bool:
        """Train gate (paper) AND out-of-sample gate (zoo)."""
        return (bool(getattr(self.model, "confident", False))
                and self.loocv_score <= self.loocv_gate)

    @property
    def r2(self) -> float:
        return getattr(self.model, "r2", -math.inf)

    def predict(self, size: float) -> float:
        return self.model.predict(size)


@dataclass
class RuntimeFit(ZooFit):
    """Zoo fit over (size, wall-time) points; same selection machinery,
    runtime-calibrated out-of-sample gate."""
    loocv_gate: float = RUNTIME_LOOCV_GATE


def _fit_candidate_zoo(sizes: Sequence[float], values: Sequence[float],
                       cands: Tuple, loocv_gate: float,
                       fallback_fit, fallback_kind: str, result_cls):
    """Shared fit/LOOCV/select core of `fit_zoo` and `fit_runtime_zoo`.

    Non-finite samples (a crashed or mis-parsed profiling run reporting
    NaN/inf) are dropped at this boundary: a single NaN otherwise poisons
    `scale` and every LOOCV score, making all `<=` comparisons False and
    the final selection unreachable.
    """
    x = np.asarray(sizes, dtype=np.float64)
    y = np.asarray(values, dtype=np.float64)
    keep = np.isfinite(x) & np.isfinite(y)
    if not bool(keep.all()):
        x, y = x[keep], y[keep]
    n = int(x.size)
    scale = float(np.abs(y).mean()) or 1.0 if n else 1.0
    fits: Dict[str, object] = {}
    scores: Dict[str, float] = {}
    train_r2: Dict[str, float] = {}
    order: List[str] = []
    for cand in cands:
        m = cand.fit(x, y)
        if m is None:
            continue
        fits[cand.kind] = m
        train_r2[cand.kind] = getattr(m, "r2", -math.inf)
        order.append(cand.kind)
        errs: Optional[List[float]] = []
        if n >= 3:
            for i in range(n):
                sub = cand.fit(np.delete(x, i), np.delete(y, i))
                if sub is None:
                    errs = None
                    break
                errs.append(sub.predict(float(x[i])) - float(y[i]))
        else:
            errs = None
        if errs:
            scores[cand.kind] = float(
                np.sqrt(np.mean(np.square(errs)))) / scale
        else:
            scores[cand.kind] = math.inf

    if not fits:     # degenerate input (n < 2): unconfident linear fallback
        return result_cls(fallback_fit(x, y), fallback_kind,
                          scores, train_r2, n, loocv_gate, fits)

    eligible = [k for k in order if getattr(fits[k], "confident", False)]
    pool = eligible or order
    best_score = min(scores[k] for k in pool)
    # absolute floor of 10% of the LOOCV gate: differences far below the
    # confidence threshold are measurement noise, and the simpler (earlier)
    # candidate — the paper's linear — should win them
    tol = best_score * 0.10 + 0.1 * loocv_gate
    # the defensive default can only trigger if a candidate's score is NaN
    # despite the finite-input filter (e.g. a pathological custom candidate)
    chosen = next((k for k in order
                   if k in pool and scores[k] <= best_score + tol), pool[0])
    return result_cls(fits[chosen], chosen, scores, train_r2, n, loocv_gate,
                      fits)


def fit_zoo(sizes: Sequence[float], mems: Sequence[float],
            candidates: Optional[Sequence] = None,
            loocv_gate: float = LOOCV_GATE) -> ZooFit:
    """Fit every candidate, score by leave-one-out CV, pick the simplest
    candidate within 10% of the best score (candidate order = simplicity
    order, linear first)."""
    cands = tuple(candidates) if candidates is not None else \
        DEFAULT_CANDIDATES
    return _fit_candidate_zoo(sizes, mems, cands, loocv_gate,
                              fit_memory_model, LinearMemoryModel.kind,
                              ZooFit)


def fit_runtime_zoo(sizes: Sequence[float], walls: Sequence[float],
                    candidates: Optional[Sequence] = None,
                    loocv_gate: float = RUNTIME_LOOCV_GATE) -> RuntimeFit:
    """Zoo fit over the ladder's per-point wall times. Same families, same
    LOOCV selection; the result ranks configs by predicted runtime (and so
    by cost) — it never gates a memory requirement."""
    cands = tuple(candidates) if candidates is not None else \
        RUNTIME_CANDIDATES
    return _fit_candidate_zoo(sizes, walls, cands, loocv_gate,
                              RuntimeLinearModel.fit,
                              RuntimeLinearModel.kind, RuntimeFit)


def zoo_fitter(candidates: Optional[Sequence] = None,
               loocv_gate: float = LOOCV_GATE):
    """A `(sizes, mems) -> model` callable for `CrispyAllocator(fitter=...)`."""
    def fitter(sizes, mems):
        return fit_zoo(sizes, mems, candidates, loocv_gate)
    return fitter
