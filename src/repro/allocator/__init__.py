"""Allocation service: batched, cached, model-zoo-backed resource allocation.

Crispy (arXiv:2206.13852) is a one-shot pipeline: sample -> profile ->
fit one linear model (R² > 0.99 gate) -> select a cluster config, with all
profiling work discarded when the gate fails. This package turns that loop
into a servable, stateful subsystem:

  model_zoo.py   Candidate-model registry — the paper's linear fit stays
                 the first/default candidate, joined by log-linear,
                 power-law and piecewise-linear fits. Leave-one-out CV
                 picks the simplest candidate within 10% of the best
                 held-out score; a `ZooFit` is a drop-in for
                 `LinearMemoryModel` (`CrispyAllocator(fitter=
                 zoo_fitter())`). Richer-candidate lineage: Ruya
                 (arXiv:2211.04240).

  registry.py    Persistent (JSON-backed, thread-safe) store of confident
                 memory models keyed by job signature — repeat requests
                 skip profiling entirely. Keeps each model's training
                 ladder so the classifier survives restarts.

  classifier.py  Flora-style nearest-job classification
                 (arXiv:2502.21046): scale-invariant features of a
                 profiling ladder (memory shape, runtime shape, and
                 categorical input-format/operator tags), nearest-neighbor
                 under a distance gate. Rescues jobs whose own profile
                 fails every model gate by transferring the neighbor's
                 model or best-known config.

  service.py     `AllocationService` — the batched/concurrent front over
                 the unified `repro.pipeline.AllocationPipeline` (the ONE
                 staged decision path, shared with the one-shot
                 `CrispyAllocator`): worker thread + futures, drain-window
                 batching, per-signature plan dedup, a cross-batch
                 ProfileResult LRU the pipeline's acquisition stage reads
                 through, and wire-facing stats. All ladder/fit/selection
                 logic lives in `repro.pipeline`; `adaptive=True` plans
                 with information-optimal point placement by default
                 (`placement="infogain"`, "ladder" keeps the PR-2
                 prefix), `budget=` enforces the paper's ten-minute
                 envelope service-wide (cached points are never charged),
                 `store=`/`backend=` share state across processes, and
                 `executor=` profiles ladders and signature groups
                 concurrently.

Serving surface: `repro.serve.engine.AllocationEndpoint` adapts the
service to dict-in/dict-out request handling next to the token-serving
`ServeEngine`; `benchmarks/allocation_service_throughput.py` measures
requests/sec and cache hit-rate; `benchmarks/profiling_adaptive.py`
compares fixed-vs-adaptive profiling cost.
"""
from repro.allocator.classifier import (Classification, NearestJobClassifier,
                                        TAG_WEIGHT, feature_distance,
                                        profile_features, runtime_features,
                                        tag_distance)
from repro.allocator.model_zoo import (DEFAULT_CANDIDATES, LOOCV_GATE,
                                       LogLinearModel, MODEL_KINDS,
                                       PiecewiseLinearModel, PowerLawModel,
                                       RUNTIME_CANDIDATES,
                                       RUNTIME_LOOCV_GATE, RUNTIME_R2_GATE,
                                       RuntimeFit, RuntimeLinearModel,
                                       RuntimeLogLinearModel,
                                       RuntimePiecewiseLinearModel,
                                       RuntimePowerLawModel, ZooFit,
                                       fit_runtime_zoo, fit_zoo,
                                       model_from_dict, model_to_dict,
                                       zoo_fitter)
from repro.allocator.registry import ModelRecord, ModelRegistry
from repro.allocator.service import (AllocationRequest, AllocationResponse,
                                     AllocationService, ServiceStats)

__all__ = [
    "AllocationRequest", "AllocationResponse", "AllocationService",
    "Classification", "DEFAULT_CANDIDATES", "LOOCV_GATE", "LogLinearModel",
    "MODEL_KINDS", "ModelRecord", "ModelRegistry", "NearestJobClassifier",
    "PiecewiseLinearModel", "PowerLawModel", "RUNTIME_CANDIDATES",
    "RUNTIME_LOOCV_GATE", "RUNTIME_R2_GATE", "RuntimeFit",
    "RuntimeLinearModel", "RuntimeLogLinearModel",
    "RuntimePiecewiseLinearModel", "RuntimePowerLawModel", "ServiceStats",
    "TAG_WEIGHT", "ZooFit", "feature_distance", "fit_runtime_zoo",
    "fit_zoo", "model_from_dict", "model_to_dict", "profile_features",
    "runtime_features", "tag_distance", "zoo_fitter",
]
