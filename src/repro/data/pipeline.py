"""Token data pipeline: datasets, sharded loader, background prefetch.

* ``SyntheticLMDataset`` — deterministic pseudo-corpus (Zipfian unigrams +
  short-range Markov structure) so training losses are meaningfully
  decreasing without external data; seeded, infinite.
* ``MemmapDataset`` — flat binary token file (np.memmap), the standard
  pre-tokenized format. Writer helper included.
* ``ShardedLoader`` — deterministic host sharding (shard i of n reads
  interleaved windows), background prefetch thread with a bounded queue,
  and a (step, epoch) cursor that serializes into checkpoints so a resumed
  run continues the stream exactly — including on a different host count
  (elastic resharding: the cursor is global, shards re-derive their slice).
  A prefetch timeout marks the batch late (straggler signal consumed by
  train/loop.py).
"""
from __future__ import annotations

import queue
import threading
import time
from dataclasses import dataclass
from typing import Iterator, Optional, Tuple

import numpy as np


class SyntheticLMDataset:
    """Infinite deterministic token stream with learnable structure."""

    def __init__(self, vocab_size: int, seed: int = 0, zipf_a: float = 1.3):
        self.vocab = vocab_size
        self.seed = seed
        ranks = np.arange(1, vocab_size + 1, dtype=np.float64)
        p = 1.0 / ranks ** zipf_a
        self.p = p / p.sum()

    def window(self, index: int, length: int) -> np.ndarray:
        rng = np.random.default_rng((self.seed, index))
        toks = rng.choice(self.vocab, size=length + 1, p=self.p)
        # inject short-range structure: every even position repeats the
        # previous token with p=.5 (a pattern a model can learn)
        mask = (np.arange(length + 1) % 2 == 0) & (rng.random(length + 1) < .5)
        toks[1:][mask[1:]] = toks[:-1][mask[1:]]
        return toks.astype(np.int32)


class MemmapDataset:
    """Flat int32 token file."""

    def __init__(self, path: str):
        self.tokens = np.memmap(path, dtype=np.int32, mode="r")

    @staticmethod
    def write(path: str, tokens: np.ndarray):
        arr = np.memmap(path, dtype=np.int32, mode="w+", shape=tokens.shape)
        arr[:] = tokens.astype(np.int32)
        arr.flush()

    def window(self, index: int, length: int) -> np.ndarray:
        n = self.tokens.shape[0]
        start = (index * length) % max(n - length - 1, 1)
        return np.asarray(self.tokens[start:start + length + 1])


@dataclass
class LoaderState:
    step: int = 0

    def to_dict(self):
        return {"step": int(self.step)}

    @staticmethod
    def from_dict(d):
        return LoaderState(int(d.get("step", 0)))


class ShardedLoader:
    """Yields {tokens, labels} host batches for shard `shard`/`n_shards`."""

    def __init__(self, dataset, batch_per_shard: int, seq_len: int,
                 shard: int = 0, n_shards: int = 1, prefetch: int = 2,
                 state: Optional[LoaderState] = None,
                 timeout_s: float = 30.0):
        self.ds = dataset
        self.B = batch_per_shard
        self.S = seq_len
        self.shard = shard
        self.n_shards = n_shards
        self.state = state or LoaderState()
        self.timeout_s = timeout_s
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        # the worker starts lazily on first __next__ so a checkpoint-restored
        # cursor (train_loop sets loader.state post-construction) takes effect
        self._thread: Optional[threading.Thread] = None
        self.late_batches = 0

    def _global_index(self, step: int, row: int) -> int:
        # global sample index: deterministic across any shard count
        return step * (self.B * self.n_shards) + self.shard * self.B + row

    def _make(self, step: int):
        toks = np.stack([self.ds.window(self._global_index(step, r), self.S)
                         for r in range(self.B)])
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def _worker(self):
        step = self.state.step
        while not self._stop.is_set():
            batch = self._make(step)
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.25)
                    break
                except queue.Full:
                    continue
            step += 1

    def __iter__(self) -> Iterator[dict]:
        return self

    def __next__(self) -> dict:
        if self._thread is None:
            self._thread = threading.Thread(target=self._worker, daemon=True)
            self._thread.start()
        t0 = time.monotonic()
        try:
            step, batch = self._q.get(timeout=self.timeout_s)
        except queue.Empty:
            # straggler mitigation: a stuck shard yields a repeat of the
            # last-known-good index rather than stalling the collective
            self.late_batches += 1
            batch = self._make(self.state.step)
            step = self.state.step
        self.state.step = step + 1
        if time.monotonic() - t0 > self.timeout_s * 0.5:
            self.late_batches += 1
        return batch

    def close(self):
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=1.0)


def make_batch_fn(vocab: int, batch: int, seq: int, seed: int = 0):
    """One-liner for tests/examples: step -> jnp-ready batch dict."""
    ds = SyntheticLMDataset(vocab, seed)

    def fn(step: int):
        toks = np.stack([ds.window(step * batch + r, seq)
                         for r in range(batch)])
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    return fn
