from repro.data.pipeline import (SyntheticLMDataset, MemmapDataset,
                                 ShardedLoader, make_batch_fn)
